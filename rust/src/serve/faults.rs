//! Deterministic fault injection for the serving daemon.
//!
//! Chaos testing is only useful when a failure reproduces: a fault plan is
//! a **pure function of (seed, request id)**, so the same plan over the
//! same request stream injects exactly the same faults no matter how the
//! daemon's threads interleave. Decisions are drawn from counter-based RNG
//! streams ([`crate::utils::Rng::stream`]) — the same keystone the
//! pipelined trainer uses for batch determinism — with one domain salt per
//! fault kind so the panic/slow/malform decisions for a request are
//! independent.
//!
//! Three fault kinds, matching the daemon's failure surfaces:
//!
//! * **worker panic** — the predict worker panics while serving the batch
//!   that contains the poisoned request (exercises supervision/respawn).
//! * **slow stage** — the predict worker sleeps before serving the batch
//!   (exercises deadline cancellation, backpressure and degradation).
//! * **malformed request** — the request line is corrupted before parsing
//!   (exercises the typed `error` response path).
//!
//! A plan comes from the `REPRO_FAULTS` environment variable (the CI chaos
//! job sets it) or a `--faults` spec:
//!
//! ```text
//! seed=7,panic=0.02,slow=0.05:3,malform=0.05
//! ```
//!
//! `panic`/`malform` are per-request probabilities; `slow=RATE:MS` sleeps
//! `MS` milliseconds on batches containing a selected request. Omitted
//! keys default to zero (fault disabled).

use crate::utils::Rng;
use anyhow::{bail, Context, Result};

/// Domain salts separating the per-kind decision streams.
const SALT_PANIC: u64 = 0x70_61_6e; // "pan"
const SALT_SLOW: u64 = 0x73_6c_6f; // "slo"
const SALT_MALFORM: u64 = 0x6d_61_6c; // "mal"

/// A seeded, reproducible fault-injection plan (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-request probability of panicking the predict worker.
    pub panic_rate: f64,
    /// Per-request probability of a slow stage.
    pub slow_rate: f64,
    /// Sleep injected when a slow stage fires (milliseconds).
    pub slow_ms: u64,
    /// Per-request probability of corrupting the request line.
    pub malform_rate: f64,
}

impl FaultPlan {
    /// A plan with every fault disabled (useful as a parse base).
    pub fn disabled(seed: u64) -> Self {
        Self { seed, panic_rate: 0.0, slow_rate: 0.0, slow_ms: 0, malform_rate: 0.0 }
    }

    /// Parse a `key=value,...` spec (see module docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::disabled(0);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("fault spec {part:?}: expected key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = value
                        .trim()
                        .parse()
                        .with_context(|| format!("fault spec seed {value:?}"))?;
                }
                "panic" => {
                    plan.panic_rate = parse_rate("panic", value)?;
                }
                "malform" => {
                    plan.malform_rate = parse_rate("malform", value)?;
                }
                "slow" => {
                    // RATE:MS, e.g. slow=0.05:3
                    let (rate, ms) = value
                        .split_once(':')
                        .with_context(|| format!("fault spec slow {value:?}: expected RATE:MS"))?;
                    plan.slow_rate = parse_rate("slow", rate)?;
                    plan.slow_ms = ms
                        .trim()
                        .parse()
                        .with_context(|| format!("fault spec slow duration {ms:?}"))?;
                }
                other => bail!("unknown fault spec key {other:?} (seed|panic|slow|malform)"),
            }
        }
        if plan.slow_rate > 0.0 && plan.slow_ms == 0 {
            bail!("fault spec: slow rate set but duration is 0 ms");
        }
        Ok(plan)
    }

    /// The `REPRO_FAULTS` plan, if the variable is set. An unparsable value
    /// is a hard error rather than a silent no-fault fallback — a CI chaos
    /// leg meant to inject faults must never quietly run clean.
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("REPRO_FAULTS") {
            Ok(spec) => Ok(Some(
                Self::parse(&spec).with_context(|| format!("invalid REPRO_FAULTS={spec:?}"))?,
            )),
            Err(_) => Ok(None),
        }
    }

    /// True when at least one fault kind can fire.
    pub fn is_active(&self) -> bool {
        self.panic_rate > 0.0 || self.slow_rate > 0.0 || self.malform_rate > 0.0
    }

    /// Uniform [0,1) draw for `(kind, request id)` — pure, order-free.
    fn draw(&self, salt: u64, request_id: u64) -> f64 {
        Rng::new(self.seed).stream(salt, request_id).next_f64()
    }

    /// Should the worker panic while serving the batch containing this
    /// request?
    pub fn worker_panic(&self, request_id: u64) -> bool {
        self.panic_rate > 0.0 && self.draw(SALT_PANIC, request_id) < self.panic_rate
    }

    /// Injected sleep for the batch containing this request, if any.
    pub fn slow_stage(&self, request_id: u64) -> Option<u64> {
        (self.slow_rate > 0.0 && self.draw(SALT_SLOW, request_id) < self.slow_rate)
            .then_some(self.slow_ms)
    }

    /// Should this request's line be corrupted before parsing?
    pub fn malform(&self, request_id: u64) -> bool {
        self.malform_rate > 0.0 && self.draw(SALT_MALFORM, request_id) < self.malform_rate
    }

    /// Corrupt a request line the way a broken client would: truncate and
    /// append a non-numeric token, so parsing fails with a typed error.
    pub fn corrupt_line(&self, line: &str) -> String {
        let keep = line.len() / 2;
        format!("{}<corrupt>", &line[..keep.min(line.len())])
    }

    /// Human-readable one-liner for startup banners.
    pub fn describe(&self) -> String {
        format!(
            "seed={} panic={} slow={}:{}ms malform={}",
            self.seed, self.panic_rate, self.slow_rate, self.slow_ms, self.malform_rate
        )
    }
}

fn parse_rate(key: &str, value: &str) -> Result<f64> {
    let rate: f64 = value
        .trim()
        .parse()
        .with_context(|| format!("fault spec {key} rate {value:?}"))?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("fault spec {key} rate {rate} not in [0, 1]");
    }
    Ok(rate)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let plan = FaultPlan::parse("seed=7,panic=0.02,slow=0.05:3,malform=0.1").unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.panic_rate, 0.02);
        assert_eq!(plan.slow_rate, 0.05);
        assert_eq!(plan.slow_ms, 3);
        assert_eq!(plan.malform_rate, 0.1);
        assert!(plan.is_active());
    }

    #[test]
    fn omitted_keys_disable_faults() {
        let plan = FaultPlan::parse("seed=3").unwrap();
        assert_eq!(plan, FaultPlan::disabled(3));
        assert!(!plan.is_active());
        for id in 0..100 {
            assert!(!plan.worker_panic(id));
            assert!(plan.slow_stage(id).is_none());
            assert!(!plan.malform(id));
        }
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FaultPlan::parse("panic").is_err(), "missing =");
        assert!(FaultPlan::parse("panic=2.0").is_err(), "rate > 1");
        assert!(FaultPlan::parse("panic=-0.1").is_err(), "rate < 0");
        assert!(FaultPlan::parse("slow=0.5").is_err(), "slow missing :MS");
        assert!(FaultPlan::parse("slow=0.5:0").is_err(), "slow with 0 ms");
        assert!(FaultPlan::parse("bogus=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("seed=x").is_err(), "bad seed");
    }

    #[test]
    fn decisions_are_pure_functions_of_seed_and_id() {
        let a = FaultPlan::parse("seed=11,panic=0.3,slow=0.3:2,malform=0.3").unwrap();
        let b = a.clone();
        for id in 0..500 {
            assert_eq!(a.worker_panic(id), b.worker_panic(id));
            assert_eq!(a.slow_stage(id), b.slow_stage(id));
            assert_eq!(a.malform(id), b.malform(id));
        }
        // query order must not matter
        let forward: Vec<bool> = (0..500).map(|id| a.worker_panic(id)).collect();
        let backward: Vec<bool> = (0..500).rev().map(|id| a.worker_panic(id)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
    }

    #[test]
    fn rates_are_roughly_respected_and_kinds_independent() {
        let plan = FaultPlan::parse("seed=5,panic=0.2,slow=0.2:1,malform=0.2").unwrap();
        let n = 20_000u64;
        let panics = (0..n).filter(|&id| plan.worker_panic(id)).count() as f64;
        let slows = (0..n).filter(|&id| plan.slow_stage(id).is_some()).count() as f64;
        let malforms = (0..n).filter(|&id| plan.malform(id)).count() as f64;
        for (kind, count) in [("panic", panics), ("slow", slows), ("malform", malforms)] {
            let frac = count / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "{kind} rate {frac} far from 0.2");
        }
        // kinds do not fire in lockstep (independent streams)
        let both = (0..n)
            .filter(|&id| plan.worker_panic(id) && plan.malform(id))
            .count() as f64;
        let frac = both / n as f64;
        assert!((frac - 0.04).abs() < 0.02, "panic∧malform rate {frac} far from 0.04");
    }

    #[test]
    fn different_seeds_give_different_plans() {
        let a = FaultPlan::parse("seed=1,panic=0.5").unwrap();
        let b = FaultPlan::parse("seed=2,panic=0.5").unwrap();
        let same = (0..256).filter(|&id| a.worker_panic(id) == b.worker_panic(id)).count();
        assert!(same < 200, "seeds 1 and 2 agree on {same}/256 decisions");
    }

    #[test]
    fn corrupt_line_breaks_float_parsing() {
        let plan = FaultPlan::disabled(0);
        let line = "0.5 1.5 2.5 3.5";
        let bad = plan.corrupt_line(line);
        assert!(bad.contains("<corrupt>"));
        assert!(bad.split_whitespace().any(|t| t.parse::<f32>().is_err()));
    }
}
