//! # adv-softmax
//!
//! Production-oriented reproduction of **"Extreme Classification via
//! Adversarial Softmax Approximation"** (Bamler & Mandt, ICLR 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — Pallas kernels and JAX graphs in
//!   `python/compile/`, AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate)** — the coordinator: auxiliary adversarial tree
//!   model ([`tree`], [`sampler`]), training loop and baselines
//!   ([`train`]), chunked evaluation with Eq. 5 bias removal ([`eval`])
//!   over the shared scoring core ([`score`]), the serving subsystem
//!   ([`serve`]: tree-guided beam top-k + batched predict pipeline + the
//!   fault-tolerant [`serve::daemon`] with deterministic fault injection
//!   via [`utils::faults`]), the distributed training-round protocol
//!   ([`dist`]: tick-driven coordinator, leased clients, bit-exact
//!   aggregation), the
//!   PJRT runtime ([`runtime`]), datasets ([`data`]) and the experiment
//!   harness ([`exp`]) that regenerates every table and figure of the
//!   paper.
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use adv_softmax::prelude::*;
//!
//! let splits = Splits::synthetic(&SyntheticConfig::preset(DatasetPreset::Tiny));
//! let registry = Registry::open_default().unwrap();
//! let cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
//! let mut run = TrainRun::prepare(&registry, &splits, &cfg).unwrap();
//! let curve = run.train().unwrap();
//! println!("final accuracy: {:.3}", curve.points.last().unwrap().accuracy);
//! ```

pub mod config;
pub mod data;
pub mod dist;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod lint;
pub mod model;
pub mod runtime;
pub mod sampler;
pub mod score;
pub mod serve;
pub mod train;
pub mod tree;
pub mod utils;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{
        DaemonConfig, DatasetPreset, DistConfig, Hyper, Method, OverlapMode, RunConfig,
        ServeConfig, SyntheticConfig, TreeConfig,
    };
    pub use crate::data::{Dataset, Splits};
    pub use crate::dist::{Coordinator, DistClient, RoundStats};
    pub use crate::eval::{EvalResult, Evaluator};
    pub use crate::model::ParamStore;
    pub use crate::runtime::Registry;
    pub use crate::sampler::{
        AdversarialSampler, FrequencySampler, NoiseSampler, UniformSampler,
    };
    pub use crate::score::Scorer;
    pub use crate::serve::daemon::{Daemon, DaemonStats};
    pub use crate::utils::faults::FaultPlan;
    pub use crate::serve::{Predictor, RequestBatcher, ServingModel};
    pub use crate::train::{LearningCurve, TrainRun};
    pub use crate::tree::Tree;
    pub use crate::utils::Rng;
}
