//! The main model's parameter store and sparse Adagrad optimizer.
//!
//! The classifier is affine-linear (paper Sec. 5): ξ_y(x, φ) = w_y·x + b_y
//! with φ = {W ∈ R^{C×K}, b ∈ R^C}. Rust owns the parameters; the HLO
//! training step consumes *gathered* rows and returns row gradients, which
//! are scattered back here with Adagrad state (Duchi et al., 2011) kept
//! per-coordinate. Sampling-based methods touch only 2B rows per step, so
//! updates are O(B·K) regardless of C.
//!
//! Gather and scatter have pool-sharded variants ([`ParamStore::gather_par`],
//! [`ParamStore::apply_sparse_par`]) that partition work by
//! `label % num_shards`: every parameter row has exactly one writer, and a
//! shard applies its rows' updates in batch order, so duplicate labels in a
//! batch update their row in exactly the serial sequence — parallel results
//! are bit-identical to the serial path. The softmax baseline's dense
//! scatter has the same treatment ([`ParamStore::apply_dense_par`]) with
//! contiguous disjoint row spans per shard.
//!
//! # Conflict-aware row leasing (double-buffered steps)
//!
//! The overlapped step engine ([`crate::train`]) gathers step *t+1*'s rows
//! **while step *t* is still executing on the device**, i.e. before *t*'s
//! scatter has landed. [`RowLeases`] makes that safe and bit-exact:
//! [`ParamStore::lease_rows`] stamps every row of *t*'s update set with a
//! fresh lease id before the eager gather starts, the eager gather
//! ([`ParamStore::gather_leased_shard`]) skips stamped rows, and after
//! `apply_sparse_par(t)` lands, [`ParamStore::patch_leased`] re-gathers
//! exactly the skipped slots. Since the scatter writes only leased rows,
//! every slot of the output ends up holding the post-scatter value — the
//! gathered buffers are bit-identical to a serial gather performed after
//! the scatter, at every worker count. Stamps are epochs, not flags, so
//! the map is never cleared: a stale stamp can never equal a live lease id.

pub mod adagrad;

pub use adagrad::Adagrad;

use crate::utils::{Pool, Rng, SharedMut};

/// Below this batch size the sharded paths fall back to the serial loop
/// (thread spawn overhead would dominate).
const PAR_MIN_LABELS: usize = 64;

/// Per-row lease stamps for the double-buffered step engine (module docs).
///
/// `stamp[y]` holds the id of the last lease that covered row `y`; ids are
/// handed out monotonically from 1, so the zero-initialized map means "no
/// row leased" and stale stamps from retired leases are inert without any
/// clearing pass.
#[derive(Clone, Debug)]
pub struct RowLeases {
    stamp: Vec<u64>,
    next_id: u64,
}

impl RowLeases {
    fn new(num_classes: usize) -> Self {
        Self { stamp: vec![0u64; num_classes], next_id: 0 }
    }

    /// Is row `y` covered by lease `id`?
    #[inline]
    pub fn is_leased(&self, y: usize, id: u64) -> bool {
        self.stamp[y] == id
    }

    /// Is row `y` covered by `since` or any newer lease? Ids are handed
    /// out monotonically, so with several steps in flight (pipeline depth
    /// 3: one scatter draining, one execute running) the set of rows an
    /// eager gather must skip is exactly `stamp >= since` for `since` =
    /// the oldest still-live lease — no per-lease bookkeeping needed.
    #[inline]
    pub fn leased_since(&self, y: usize, since: u64) -> bool {
        self.stamp[y] >= since
    }
}

/// Dense parameter matrix (W, b) with per-coordinate Adagrad accumulators.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub num_classes: usize,
    pub feat_dim: usize,
    /// Row-major [C, K].
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub opt: Adagrad,
    /// Touched-row epoch map for the overlapped step protocol.
    pub leases: RowLeases,
}

impl ParamStore {
    /// Zero-initialized parameters (the convex objective needs no random
    /// init; zero scores mean σ(ξ)=1/2 everywhere).
    pub fn zeros(num_classes: usize, feat_dim: usize, lr: f32) -> Self {
        Self {
            num_classes,
            feat_dim,
            w: vec![0f32; num_classes * feat_dim],
            b: vec![0f32; num_classes],
            opt: Adagrad::new(num_classes, feat_dim, lr),
            leases: RowLeases::new(num_classes),
        }
    }

    /// Small random init (used by the SNR experiment to start near but not
    /// at the symmetric point).
    pub fn random(num_classes: usize, feat_dim: usize, lr: f32, scale: f32, rng: &mut Rng) -> Self {
        let mut s = Self::zeros(num_classes, feat_dim, lr);
        for v in s.w.iter_mut() {
            *v = scale * rng.normal();
        }
        s
    }

    #[inline]
    pub fn row(&self, y: u32) -> &[f32] {
        let y = y as usize;
        &self.w[y * self.feat_dim..(y + 1) * self.feat_dim]
    }

    /// Gather label rows into a dense [B, K] buffer + [B] bias buffer.
    pub fn gather(&self, labels: &[u32], w_out: &mut [f32], b_out: &mut [f32]) {
        debug_assert_eq!(w_out.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(b_out.len(), labels.len());
        for (i, &y) in labels.iter().enumerate() {
            w_out[i * self.feat_dim..(i + 1) * self.feat_dim].copy_from_slice(self.row(y));
            b_out[i] = self.b[y as usize];
        }
    }

    /// Scatter row gradients back with an Adagrad update. Duplicate labels
    /// in the batch are applied sequentially (equivalent to processing the
    /// batch as B independent SGD examples).
    pub fn apply_sparse(&mut self, labels: &[u32], gw: &[f32], gb: &[f32]) {
        debug_assert_eq!(gw.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(gb.len(), labels.len());
        let k = self.feat_dim;
        for (i, &y) in labels.iter().enumerate() {
            self.opt.update_row(
                y as usize,
                &gw[i * k..(i + 1) * k],
                gb[i],
                &mut self.w,
                &mut self.b,
            );
        }
    }

    /// Pool-sharded [`ParamStore::gather`]: shard `labels[i] % S` copies
    /// batch slot `i`, so each output row has exactly one writer and the
    /// result is identical to the serial gather at any worker count.
    pub fn gather_par(&self, pool: &Pool, labels: &[u32], w_out: &mut [f32], b_out: &mut [f32]) {
        if pool.is_serial() || labels.len() < PAR_MIN_LABELS {
            return self.gather(labels, w_out, b_out);
        }
        debug_assert_eq!(w_out.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(b_out.len(), labels.len());
        let k = self.feat_dim;
        let shards = pool.num_workers();
        let w_view = SharedMut::new(w_out);
        let b_view = SharedMut::new(b_out);
        pool.run_sharded(|shard| {
            for (i, &y) in labels.iter().enumerate() {
                if (y as usize) % shards != shard {
                    continue;
                }
                // SAFETY: batch slot i is written only by the shard owning
                // labels[i] (one label per slot => disjoint slots).
                unsafe {
                    w_view.slice_mut(i * k, k).copy_from_slice(self.row(y));
                    *b_view.get_mut(i) = self.b[y as usize];
                }
            }
        });
    }

    /// Pool-sharded [`ParamStore::apply_sparse`]: shard `label % S` owns
    /// all updates to its rows and applies them in batch order, preserving
    /// the exact sequential-per-row Adagrad semantics for duplicate labels.
    /// Bit-identical to the serial scatter at any worker count.
    pub fn apply_sparse_par(&mut self, pool: &Pool, labels: &[u32], gw: &[f32], gb: &[f32]) {
        if pool.is_serial() || labels.len() < PAR_MIN_LABELS {
            return self.apply_sparse(labels, gw, gb);
        }
        debug_assert_eq!(gw.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(gb.len(), labels.len());
        let k = self.feat_dim;
        let shards = pool.num_workers();
        let (lr, eps) = (self.opt.lr, self.opt.eps);
        let (gw2, gb2) = self.opt.accumulators_mut();
        let w_view = SharedMut::new(&mut self.w);
        let b_view = SharedMut::new(&mut self.b);
        let gw2_view = SharedMut::new(gw2);
        let gb2_view = SharedMut::new(gb2);
        pool.run_sharded(|shard| {
            for (i, &y) in labels.iter().enumerate() {
                let y = y as usize;
                if y % shards != shard {
                    continue;
                }
                // SAFETY: row y (weights, bias, both accumulators) is
                // touched only by shard y % shards; within the shard,
                // updates run in batch order like the serial scatter.
                unsafe {
                    adagrad::update_row_kernel(
                        lr,
                        eps,
                        &gw[i * k..(i + 1) * k],
                        gb[i],
                        gw2_view.slice_mut(y * k, k),
                        w_view.slice_mut(y * k, k),
                        gb2_view.get_mut(y),
                        b_view.get_mut(y),
                    );
                }
            }
        });
    }

    /// Lease every row named in `label_sets` (the pos+neg label sets of
    /// the step about to execute) under a fresh lease id. Rows leased here
    /// are exactly the rows the step's scatter will update, so the
    /// overlapped eager gather of the *next* step must skip them and
    /// [`ParamStore::patch_leased`] must re-read them once the scatter has
    /// landed (module docs).
    pub fn lease_rows(&mut self, label_sets: &[&[u32]]) -> u64 {
        self.leases.next_id += 1;
        let id = self.leases.next_id;
        for labels in label_sets {
            for &y in labels.iter() {
                self.leases.stamp[y as usize] = id;
            }
        }
        id
    }

    /// One shard of the conflict-aware eager gather: copy batch slot `i`
    /// (for every `i` with `labels[i] % num_shards == shard`) into the
    /// output views, **skipping** rows covered by `lease` or any newer
    /// lease — those rows are about to be rewritten by an in-flight step's
    /// scatter and are patched afterwards. Runs concurrently with the
    /// device execute via [`Pool::submit_sharded`]; nothing writes the
    /// parameters during that window, so the reads are race-free.
    ///
    /// Safety contract (upheld by the shard map, as in
    /// [`ParamStore::gather_par`]): batch slot `i` is written only by the
    /// shard owning `labels[i]`, and the views must cover
    /// `labels.len() * feat_dim` / `labels.len()` elements.
    pub fn gather_leased_shard(
        &self,
        labels: &[u32],
        lease: u64,
        num_shards: usize,
        shard: usize,
        w_view: &SharedMut<'_, f32>,
        b_view: &SharedMut<'_, f32>,
    ) {
        debug_assert_eq!(w_view.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(b_view.len(), labels.len());
        let k = self.feat_dim;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            if yu % num_shards != shard || self.leases.leased_since(yu, lease) {
                continue;
            }
            // SAFETY: slot i has exactly one writer (the shard owning
            // labels[i]); see the method's safety contract.
            unsafe {
                w_view.slice_mut(i * k, k).copy_from_slice(self.row(y));
                *b_view.get_mut(i) = self.b[yu];
            }
        }
    }

    /// Complete an eager gather after the conflicting scatter has landed:
    /// re-copy every batch slot whose row is covered by `lease` or newer
    /// (exactly the slots [`ParamStore::gather_leased_shard`] skipped).
    /// Returns the number of patched slots. After this, the output buffers
    /// are bit-identical to a serial gather performed after the scatter.
    pub fn patch_leased(
        &self,
        labels: &[u32],
        lease: u64,
        w_out: &mut [f32],
        b_out: &mut [f32],
    ) -> usize {
        debug_assert_eq!(w_out.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(b_out.len(), labels.len());
        let k = self.feat_dim;
        let mut patched = 0;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            if self.leases.leased_since(yu, lease) {
                w_out[i * k..(i + 1) * k].copy_from_slice(self.row(y));
                b_out[i] = self.b[yu];
                patched += 1;
            }
        }
        patched
    }

    /// Two-phase patch for pipeline depth 3. With two steps still in
    /// flight, a gathered batch's skipped slots split by lease epoch:
    /// rows stamped in `[since, below)` belong to leases whose scatter has
    /// fully landed — patch them now — while rows stamped `>= below` (the
    /// executing step's lease) still await that step's conflict scatter;
    /// their slot indices are pushed onto `deferred` for a later
    /// [`ParamStore::patch_slots`]. Returns the number patched now.
    pub fn patch_leased_range(
        &self,
        labels: &[u32],
        since: u64,
        below: u64,
        w_out: &mut [f32],
        b_out: &mut [f32],
        deferred: &mut Vec<u32>,
    ) -> usize {
        debug_assert_eq!(w_out.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(b_out.len(), labels.len());
        let k = self.feat_dim;
        let mut patched = 0;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            let stamp = self.leases.stamp[yu];
            if stamp >= below {
                deferred.push(i as u32);
            } else if stamp >= since {
                w_out[i * k..(i + 1) * k].copy_from_slice(self.row(y));
                b_out[i] = self.b[yu];
                patched += 1;
            }
        }
        patched
    }

    /// Patch the recorded `slots` of a gathered batch from the current
    /// parameters (the deferred half of [`ParamStore::patch_leased_range`],
    /// run once the executing step's conflict scatter has landed).
    pub fn patch_slots(&self, labels: &[u32], slots: &[u32], w_out: &mut [f32], b_out: &mut [f32]) {
        let k = self.feat_dim;
        for &i in slots {
            let i = i as usize;
            let yu = labels[i] as usize;
            w_out[i * k..(i + 1) * k].copy_from_slice(self.row(labels[i]));
            b_out[i] = self.b[yu];
        }
    }

    /// The conflict half of a split scatter (pipeline depth 3): apply, in
    /// batch order, exactly the updates whose row is stamped `lease_eq` —
    /// the rows the *next* step's gather skipped and must read
    /// post-update. The remainder (rows stamped with the step's own,
    /// older lease) is applied concurrently with the next execute via
    /// [`ParamStageViews::scatter_shard`]. The split is by row, so every
    /// row sees its updates in the exact serial sequence. Returns the
    /// number of updates applied.
    pub fn apply_sparse_stamped(
        &mut self,
        labels: &[u32],
        gw: &[f32],
        gb: &[f32],
        lease_eq: u64,
    ) -> usize {
        debug_assert_eq!(gw.len(), labels.len() * self.feat_dim);
        debug_assert_eq!(gb.len(), labels.len());
        let k = self.feat_dim;
        let mut applied = 0;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            if self.leases.stamp[yu] != lease_eq {
                continue;
            }
            self.opt.update_row(yu, &gw[i * k..(i + 1) * k], gb[i], &mut self.w, &mut self.b);
            applied += 1;
        }
        applied
    }

    /// Disjoint raw views over the parameter/optimizer/lease state for the
    /// pipelined engine's combined background stage, which both scatters
    /// step *t*'s non-conflict rows and eagerly gathers step *t+2*'s
    /// unleased rows while step *t+1* executes on the device thread. The
    /// combination is race-free by construction: the scatter writes only
    /// rows stamped with *t*'s lease, the gather reads only rows below the
    /// oldest live lease, and both shard rows by `label % num_shards` so
    /// every row has exactly one owner (checked under `shared_mut_audit`).
    pub fn stage_views(&mut self) -> ParamStageViews<'_> {
        let (lr, eps) = (self.opt.lr, self.opt.eps);
        let k = self.feat_dim;
        let (gw2, gb2) = self.opt.accumulators_mut();
        ParamStageViews {
            w: SharedMut::new(&mut self.w),
            b: SharedMut::new(&mut self.b),
            gw2: SharedMut::new(gw2),
            gb2: SharedMut::new(gb2),
            stamp: &self.leases.stamp,
            lr,
            eps,
            k,
        }
    }

    /// Dense update over all rows (full-softmax baseline).
    pub fn apply_dense(&mut self, gw: &[f32], gb: &[f32]) {
        debug_assert_eq!(gw.len(), self.w.len());
        debug_assert_eq!(gb.len(), self.b.len());
        let k = self.feat_dim;
        for y in 0..self.num_classes {
            self.opt.update_row(y, &gw[y * k..(y + 1) * k], gb[y], &mut self.w, &mut self.b);
        }
    }

    /// Pool-sharded [`ParamStore::apply_dense`]: rows are partitioned into
    /// one contiguous span per shard (a pure function of `(C, workers)`),
    /// and each row's Adagrad update touches only that row's weights, bias
    /// and accumulators — every index has exactly one writer, and per-row
    /// updates are the same floating-point program as the serial loop, so
    /// the scatter is bit-identical at any worker count (matching the
    /// `apply_sparse_par` semantics).
    pub fn apply_dense_par(&mut self, pool: &Pool, gw: &[f32], gb: &[f32]) {
        if pool.is_serial() || self.num_classes < PAR_MIN_LABELS {
            return self.apply_dense(gw, gb);
        }
        debug_assert_eq!(gw.len(), self.w.len());
        debug_assert_eq!(gb.len(), self.b.len());
        let k = self.feat_dim;
        let c = self.num_classes;
        let per = c.div_ceil(pool.num_workers());
        let (lr, eps) = (self.opt.lr, self.opt.eps);
        let (gw2, gb2) = self.opt.accumulators_mut();
        let w_view = SharedMut::new(&mut self.w);
        let b_view = SharedMut::new(&mut self.b);
        let gw2_view = SharedMut::new(gw2);
        let gb2_view = SharedMut::new(gb2);
        pool.run_sharded(|shard| {
            let lo = (shard * per).min(c);
            let hi = ((shard + 1) * per).min(c);
            for y in lo..hi {
                // SAFETY: row y (weights, bias, both accumulators) lies in
                // exactly one shard's contiguous [lo, hi) span.
                unsafe {
                    adagrad::update_row_kernel(
                        lr,
                        eps,
                        &gw[y * k..(y + 1) * k],
                        gb[y],
                        gw2_view.slice_mut(y * k, k),
                        w_view.slice_mut(y * k, k),
                        gb2_view.get_mut(y),
                        b_view.get_mut(y),
                    );
                }
            }
        });
    }
}

/// Borrowed views for the depth-3 engine's combined background stage
/// (see [`ParamStore::stage_views`]). The coordinator cannot hold
/// `&ParamStore` inside a [`Pool::submit_sharded`] closure while it also
/// needs `&mut ParamStore` for the serial conflict scatter, so the stage
/// captures these raw views instead; lease stamps are snapshotted as a
/// plain shared borrow (nothing restamps rows while a stage is in
/// flight).
pub struct ParamStageViews<'a> {
    w: SharedMut<'a, f32>,
    b: SharedMut<'a, f32>,
    gw2: SharedMut<'a, f32>,
    gb2: SharedMut<'a, f32>,
    stamp: &'a [u64],
    lr: f32,
    eps: f32,
    k: usize,
}

impl ParamStageViews<'_> {
    /// View-based [`ParamStore::gather_leased_shard`]: copy batch slot `i`
    /// (for every `i` with `labels[i] % num_shards == shard`) into the
    /// output views, skipping rows stamped `>= since` (covered by any
    /// still-live lease; their scatters have not all landed).
    ///
    /// Safety contract (as in [`ParamStore::gather_leased_shard`]): batch
    /// slot `i` is written only by the shard owning `labels[i]`, and the
    /// gathered rows are disjoint from every row a concurrent
    /// [`ParamStageViews::scatter_shard`] writes (`stamp < since` here vs
    /// `stamp == lease_eq >= since` there).
    pub fn gather_shard(
        &self,
        labels: &[u32],
        since: u64,
        num_shards: usize,
        shard: usize,
        w_out: &SharedMut<'_, f32>,
        b_out: &SharedMut<'_, f32>,
    ) {
        debug_assert_eq!(w_out.len(), labels.len() * self.k);
        debug_assert_eq!(b_out.len(), labels.len());
        let k = self.k;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            if yu % num_shards != shard || self.stamp[yu] >= since {
                continue;
            }
            // SAFETY: slot i has one writer (the shard owning labels[i]);
            // row yu is unleased, so no concurrent scatter_shard writes it
            // (see the method's safety contract).
            unsafe {
                w_out.slice_mut(i * k, k).copy_from_slice(self.w.slice_mut(yu * k, k));
                *b_out.get_mut(i) = *self.b.get_mut(yu);
            }
        }
    }

    /// View-based remainder scatter: apply, in batch order, the updates
    /// whose row is stamped exactly `lease_eq` (the executing step's own
    /// lease) and owned by this shard (`label % num_shards == shard`).
    /// Together with the serial [`ParamStore::apply_sparse_stamped`]
    /// conflict pass this applies every update of the batch exactly once,
    /// each row's updates in serial batch order.
    pub fn scatter_shard(
        &self,
        labels: &[u32],
        gw: &[f32],
        gb: &[f32],
        lease_eq: u64,
        num_shards: usize,
        shard: usize,
    ) {
        debug_assert_eq!(gw.len(), labels.len() * self.k);
        debug_assert_eq!(gb.len(), labels.len());
        let k = self.k;
        for (i, &y) in labels.iter().enumerate() {
            let yu = y as usize;
            if yu % num_shards != shard || self.stamp[yu] != lease_eq {
                continue;
            }
            // SAFETY: row yu (weights, bias, both accumulators) is written
            // only by shard yu % num_shards, in batch order within the
            // shard; concurrent gather_shard calls skip leased rows, so
            // nothing reads row yu while it is updated.
            unsafe {
                adagrad::update_row_kernel(
                    self.lr,
                    self.eps,
                    &gw[i * k..(i + 1) * k],
                    gb[i],
                    self.gw2.slice_mut(yu * k, k),
                    self.w.slice_mut(yu * k, k),
                    self.gb2.get_mut(yu),
                    self.b.get_mut(yu),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_roundtrip() {
        let mut p = ParamStore::zeros(4, 3, 0.1);
        p.w.copy_from_slice(&[
            0.0, 0.1, 0.2, //
            1.0, 1.1, 1.2, //
            2.0, 2.1, 2.2, //
            3.0, 3.1, 3.2,
        ]);
        p.b.copy_from_slice(&[0.5, 1.5, 2.5, 3.5]);
        let labels = [2u32, 0, 2];
        let mut w = vec![0f32; 9];
        let mut b = vec![0f32; 3];
        p.gather(&labels, &mut w, &mut b);
        assert_eq!(&w[0..3], &[2.0, 2.1, 2.2]);
        assert_eq!(&w[3..6], &[0.0, 0.1, 0.2]);
        assert_eq!(b, vec![2.5, 0.5, 2.5]);
    }

    #[test]
    fn sparse_update_only_touches_given_rows() {
        let mut p = ParamStore::zeros(4, 2, 0.5);
        let labels = [1u32];
        p.apply_sparse(&labels, &[1.0, -1.0], &[2.0]);
        assert_eq!(&p.w[0..2], &[0.0, 0.0]);
        assert_ne!(&p.w[2..4], &[0.0, 0.0]);
        assert_eq!(&p.w[4..8], &[0.0; 4]);
        assert_eq!(p.b[0], 0.0);
        assert_ne!(p.b[1], 0.0);
    }

    #[test]
    fn update_moves_against_gradient() {
        let mut p = ParamStore::zeros(2, 2, 0.1);
        p.apply_sparse(&[0], &[1.0, -2.0], &[3.0]);
        assert!(p.w[0] < 0.0);
        assert!(p.w[1] > 0.0);
        assert!(p.b[0] < 0.0);
    }

    #[test]
    fn duplicate_labels_accumulate() {
        let mut a = ParamStore::zeros(2, 1, 0.1);
        let mut b = ParamStore::zeros(2, 1, 0.1);
        a.apply_sparse(&[0, 0], &[1.0, 1.0], &[0.0, 0.0]);
        b.apply_sparse(&[0], &[1.0], &[0.0]);
        assert!(a.w[0] < b.w[0], "{} vs {}", a.w[0], b.w[0]);
    }

    #[test]
    fn gather_par_matches_serial() {
        let mut rng = Rng::new(21);
        let (c, k, b) = (37, 8, 300); // b > PAR_MIN_LABELS to hit the pool
        let mut p = ParamStore::zeros(c, k, 0.1);
        p.w.iter_mut().for_each(|v| *v = rng.normal());
        p.b.iter_mut().for_each(|v| *v = rng.normal());
        let labels: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let mut w_ref = vec![0f32; b * k];
        let mut b_ref = vec![0f32; b];
        p.gather(&labels, &mut w_ref, &mut b_ref);
        for workers in [2, 3, 4] {
            let mut w_par = vec![0f32; b * k];
            let mut b_par = vec![0f32; b];
            p.gather_par(&Pool::new(workers), &labels, &mut w_par, &mut b_par);
            assert_eq!(w_par, w_ref, "workers={workers}");
            assert_eq!(b_par, b_ref, "workers={workers}");
        }
    }

    #[test]
    fn sharded_scatter_is_bit_identical_with_duplicates() {
        let mut rng = Rng::new(22);
        let (c, k, b) = (19, 8, 300); // heavy duplication: b >> c
        let labels: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let mut serial = ParamStore::zeros(c, k, 0.1);
        serial.apply_sparse(&labels, &gw, &gb);
        serial.apply_sparse(&labels, &gw, &gb); // accumulators persist
        for workers in [2, 3, 4] {
            let mut par = ParamStore::zeros(c, k, 0.1);
            let pool = Pool::new(workers);
            par.apply_sparse_par(&pool, &labels, &gw, &gb);
            par.apply_sparse_par(&pool, &labels, &gw, &gb);
            assert_eq!(par.w, serial.w, "workers={workers}");
            assert_eq!(par.b, serial.b, "workers={workers}");
        }
    }

    /// Leased gather + patch reproduces a serial gather-after-scatter bit
    /// for bit, even when every row of the next batch conflicts.
    #[test]
    fn leased_gather_plus_patch_equals_gather_after_scatter() {
        let mut rng = Rng::new(31);
        let (c, k, b) = (23, 6, 120);
        let mut p = ParamStore::zeros(c, k, 0.1);
        p.w.iter_mut().for_each(|v| *v = rng.normal());
        p.b.iter_mut().for_each(|v| *v = rng.normal());
        // step t's update set and gradients
        let cur: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        // step t+1's labels, overlapping heavily with cur (b >> c)
        let nxt: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();

        // serial protocol: scatter, then gather
        let mut serial = p.clone();
        serial.apply_sparse(&cur, &gw, &gb);
        let mut w_ref = vec![0f32; b * k];
        let mut b_ref = vec![0f32; b];
        serial.gather(&nxt, &mut w_ref, &mut b_ref);

        for workers in [1usize, 2, 3, 5] {
            let pool = Pool::new(workers);
            let mut par = p.clone();
            let lease = par.lease_rows(&[&cur]);
            let mut w_out = vec![f32::NAN; b * k]; // poisoned: every slot must be written
            let mut b_out = vec![f32::NAN; b];
            {
                let w_view = SharedMut::new(&mut w_out);
                let b_view = SharedMut::new(&mut b_out);
                let par_ref = &par;
                let nxt_ref = &nxt;
                let shards = pool.stage_shards();
                let handle = pool.submit_sharded(move |shard| {
                    par_ref.gather_leased_shard(nxt_ref, lease, shards, shard, &w_view, &b_view);
                });
                handle.join();
            }
            par.apply_sparse_par(&pool, &cur, &gw, &gb);
            let patched = par.patch_leased(&nxt, lease, &mut w_out, &mut b_out);
            let expect_patched =
                nxt.iter().filter(|&&y| cur.contains(&y)).count();
            assert_eq!(patched, expect_patched, "workers={workers}");
            assert_eq!(w_out, w_ref, "workers={workers}");
            assert_eq!(b_out, b_ref, "workers={workers}");
        }
    }

    /// Depth-3 protocol at the store level: two consecutive scatters are
    /// each split into a serial conflict pass (rows the next batch reads,
    /// restamped to the next lease) and a sharded remainder pass that runs
    /// concurrently with the following eager gather. Buffers and params
    /// must come out bit-identical to the fully serial
    /// scatter/scatter/gather sequence at every worker count.
    #[test]
    fn split_scatter_with_two_live_leases_matches_serial() {
        let mut rng = Rng::new(47);
        let (c, k, b) = (23, 6, 120);
        let mut p = ParamStore::zeros(c, k, 0.1);
        p.w.iter_mut().for_each(|v| *v = rng.normal());
        p.b.iter_mut().for_each(|v| *v = rng.normal());
        // three consecutive batches, overlapping heavily (b >> c)
        let b1: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw1: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb1: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let b2: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw2: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb2: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let b3: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();

        // serial protocol: scatter(b1); gather(b2); scatter(b2); gather(b3)
        let mut serial = p.clone();
        serial.apply_sparse(&b1, &gw1, &gb1);
        let mut w2_ref = vec![0f32; b * k];
        let mut b2_ref = vec![0f32; b];
        serial.gather(&b2, &mut w2_ref, &mut b2_ref);
        serial.apply_sparse(&b2, &gw2, &gb2);
        let mut w3_ref = vec![0f32; b * k];
        let mut b3_ref = vec![0f32; b];
        serial.gather(&b3, &mut w3_ref, &mut b3_ref);

        for workers in [1usize, 2, 3, 5] {
            let pool = Pool::new(workers);
            let shards = pool.stage_shards();
            let mut par = p.clone();

            // step 1 launches: lease b1, eager-gather b2 while it "executes"
            let l1 = par.lease_rows(&[&b1]);
            let mut w2_out = vec![f32::NAN; b * k]; // poisoned: every slot must be written
            let mut b2_out = vec![f32::NAN; b];
            {
                let views = par.stage_views();
                let views_ref = &views;
                let w_view = SharedMut::new(&mut w2_out);
                let b_view = SharedMut::new(&mut b2_out);
                let b2_ref2 = &b2;
                let handle = pool.submit_sharded(move |shard| {
                    views_ref.gather_shard(b2_ref2, l1, shards, shard, &w_view, &b_view);
                });
                handle.join();
            }

            // step 1 joins: phase-A patch on b2 has nothing landed yet
            // ([l1, l1) is empty) — every conflicting slot defers
            let mut deferred = Vec::new();
            let patched =
                par.patch_leased_range(&b2, l1, l1, &mut w2_out, &mut b2_out, &mut deferred);
            assert_eq!(patched, 0, "no lease below l1 has landed");
            assert_eq!(
                deferred.len(),
                b2.iter().filter(|y| b1.contains(y)).count(),
                "deferred slots are exactly b2's rows still under b1's lease"
            );
            let l2 = par.lease_rows(&[&b2]);
            // conflict half of scatter(b1): rows b2 re-leased (b1 ∩ b2)
            par.apply_sparse_stamped(&b1, &gw1, &gb1, l2);
            par.patch_slots(&b2, &deferred, &mut w2_out, &mut b2_out);
            assert_eq!(w2_out, w2_ref, "workers={workers}: b2 gather diverged");
            assert_eq!(b2_out, b2_ref, "workers={workers}: b2 bias gather diverged");

            // step 2 executes: remainder of scatter(b1) (rows still stamped
            // l1) runs concurrently with b3's eager gather, one pool stage
            let mut w3_out = vec![f32::NAN; b * k];
            let mut b3_out = vec![f32::NAN; b];
            {
                let views = par.stage_views();
                let views_ref = &views;
                let w_view = SharedMut::new(&mut w3_out);
                let b_view = SharedMut::new(&mut b3_out);
                let (b1_r, gw1_r, gb1_r, b3_r) = (&b1, &gw1, &gb1, &b3);
                let handle = pool.submit_sharded(move |shard| {
                    views_ref.scatter_shard(b1_r, gw1_r, gb1_r, l1, shards, shard);
                    views_ref.gather_shard(b3_r, l1, shards, shard, &w_view, &b_view);
                });
                handle.join();
            }

            // step 2 joins: rows in [l1, l2) have fully landed, rows still
            // under l2 (b2's lease) defer until b2's conflict scatter
            let mut deferred3 = Vec::new();
            par.patch_leased_range(&b3, l1, l2, &mut w3_out, &mut b3_out, &mut deferred3);
            let l3 = par.lease_rows(&[&b3]);
            par.apply_sparse_stamped(&b2, &gw2, &gb2, l3);
            par.patch_slots(&b3, &deferred3, &mut w3_out, &mut b3_out);
            assert_eq!(w3_out, w3_ref, "workers={workers}: b3 gather diverged");
            assert_eq!(b3_out, b3_ref, "workers={workers}: b3 bias gather diverged");

            // drain: remainder of scatter(b2) — params now fully caught up
            {
                let views = par.stage_views();
                let views_ref = &views;
                let (b2_r, gw2_r, gb2_r) = (&b2, &gw2, &gb2);
                let handle = pool.submit_sharded(move |shard| {
                    views_ref.scatter_shard(b2_r, gw2_r, gb2_r, l2, shards, shard);
                });
                handle.join();
            }
            assert_eq!(par.w, serial.w, "workers={workers}: weights diverged");
            assert_eq!(par.b, serial.b, "workers={workers}: biases diverged");
        }
    }

    /// Stale stamps from an old lease never leak into a newer lease's
    /// conflict checks.
    #[test]
    fn lease_ids_do_not_alias_across_steps() {
        let mut p = ParamStore::zeros(8, 2, 0.1);
        let l1 = p.lease_rows(&[&[1u32, 3]]);
        let l2 = p.lease_rows(&[&[3u32, 5]]);
        assert_ne!(l1, l2);
        assert!(!p.leases.is_leased(1, l2), "row 1 belongs to the old lease only");
        assert!(p.leases.is_leased(3, l2), "row 3 re-leased under the new id");
        assert!(p.leases.is_leased(5, l2));
        assert!(!p.leases.is_leased(0, l2));
        // the old id is retired: nothing should match it after re-lease
        assert!(p.leases.is_leased(1, l1), "non-conflicting old row keeps its stamp");
        assert!(!p.leases.is_leased(3, l1), "re-leased row left the old lease");
    }

    #[test]
    fn dense_update_touches_all_rows() {
        let mut p = ParamStore::zeros(3, 1, 0.1);
        p.apply_dense(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]);
        assert!(p.w.iter().all(|&v| v < 0.0));
        assert!(p.b.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn sharded_dense_scatter_is_bit_identical() {
        let mut rng = Rng::new(23);
        let (c, k) = (101, 7); // c > PAR_MIN_LABELS, not a shard multiple
        let gw: Vec<f32> = (0..c * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
        let mut serial = ParamStore::zeros(c, k, 0.1);
        serial.apply_dense(&gw, &gb);
        serial.apply_dense(&gw, &gb); // accumulators persist across steps
        for workers in [2, 3, 5] {
            let pool = Pool::new(workers);
            let mut par = ParamStore::zeros(c, k, 0.1);
            par.apply_dense_par(&pool, &gw, &gb);
            par.apply_dense_par(&pool, &gw, &gb);
            assert_eq!(par.w, serial.w, "workers={workers}");
            assert_eq!(par.b, serial.b, "workers={workers}");
        }
    }
}
