//! Per-coordinate Adagrad (Duchi et al., 2011), the optimizer used for the
//! proposed method and baselines (i)-(iii) in the paper's experiments.
//!
//! State is one accumulator per parameter: G += g²; θ -= ρ g / (√G + ε).
//! Kept separate from [`super::ParamStore`] so trainers can reset or swap
//! optimizer state without touching parameters.
//!
//! Under the double-buffered step engine ([`crate::train`]), the Adagrad
//! scatter is the **only** writer of parameters and accumulators between
//! the eager gather of the next step and its post-scatter patch
//! ([`super::ParamStore::patch_leased`]): the row-lease protocol stamps
//! every row this scatter will touch *before* the eager gather starts, so
//! overlapped and serial runs apply the exact same `update_row_kernel`
//! sequence per row — learning curves stay bit-identical.

/// Adagrad accumulators for a [C, K] weight matrix + [C] bias vector.
#[derive(Clone, Debug)]
pub struct Adagrad {
    pub lr: f32,
    pub eps: f32,
    gw2: Vec<f32>,
    gb2: Vec<f32>,
    feat_dim: usize,
}

impl Adagrad {
    pub fn new(num_classes: usize, feat_dim: usize, lr: f32) -> Self {
        Self {
            lr,
            eps: 1e-8,
            gw2: vec![0f32; num_classes * feat_dim],
            gb2: vec![0f32; num_classes],
            feat_dim,
        }
    }

    /// Apply one row update: g is the gradient of row `y`, gb the bias grad.
    #[inline]
    pub fn update_row(&mut self, y: usize, g: &[f32], gb: f32, w: &mut [f32], b: &mut [f32]) {
        let k = self.feat_dim;
        debug_assert_eq!(g.len(), k);
        update_row_kernel(
            self.lr,
            self.eps,
            g,
            gb,
            &mut self.gw2[y * k..(y + 1) * k],
            &mut w[y * k..(y + 1) * k],
            &mut self.gb2[y],
            &mut b[y],
        );
    }

    /// Split borrows of the (weight, bias) accumulators, for the sharded
    /// scatter in [`super::ParamStore::apply_sparse_par`].
    pub(crate) fn accumulators_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut self.gw2, &mut self.gb2)
    }

    /// Read-only view of the (weight, bias) accumulators, for the dist
    /// layer's bit-exact parameter snapshots and checksums.
    pub(crate) fn accumulators(&self) -> (&[f32], &[f32]) {
        (&self.gw2, &self.gb2)
    }

    /// Reset all accumulators (e.g. between experiment repetitions).
    pub fn reset(&mut self) {
        self.gw2.iter_mut().for_each(|v| *v = 0.0);
        self.gb2.iter_mut().for_each(|v| *v = 0.0);
    }
}

/// The per-row Adagrad update on raw slices: G += g²; θ -= ρ g / (√G + ε).
/// Shared by the serial [`Adagrad::update_row`] and the sharded scatter so
/// both paths are the same floating-point program (bit-identical results).
#[inline]
pub(crate) fn update_row_kernel(
    lr: f32,
    eps: f32,
    g: &[f32],
    gb: f32,
    acc: &mut [f32],
    row: &mut [f32],
    bacc: &mut f32,
    bval: &mut f32,
) {
    debug_assert_eq!(g.len(), acc.len());
    debug_assert_eq!(g.len(), row.len());
    for j in 0..g.len() {
        let gj = g[j];
        acc[j] += gj * gj;
        row[j] -= lr * gj / (acc[j].sqrt() + eps);
    }
    *bacc += gb * gb;
    *bval -= lr * gb / (bacc.sqrt() + eps);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // With G = g², the first update is -lr * g/|g| = -lr * sign(g).
        let mut opt = Adagrad::new(1, 2, 0.1);
        let mut w = vec![0f32; 2];
        let mut b = vec![0f32; 1];
        opt.update_row(0, &[4.0, -0.25], 1.0, &mut w, &mut b);
        assert!((w[0] + 0.1).abs() < 1e-4);
        assert!((w[1] - 0.1).abs() < 1e-4);
        assert!((b[0] + 0.1).abs() < 1e-4);
    }

    #[test]
    fn steps_shrink_over_time() {
        let mut opt = Adagrad::new(1, 1, 0.1);
        let mut w = vec![0f32; 1];
        let mut b = vec![0f32; 1];
        let mut prev = 0f32;
        let mut deltas = vec![];
        for _ in 0..5 {
            opt.update_row(0, &[1.0], 0.0, &mut w, &mut b);
            deltas.push((w[0] - prev).abs());
            prev = w[0];
        }
        for i in 1..deltas.len() {
            assert!(deltas[i] < deltas[i - 1]);
        }
    }

    #[test]
    fn reset_restores_first_step_size() {
        let mut opt = Adagrad::new(1, 1, 0.1);
        let mut w = vec![0f32; 1];
        let mut b = vec![0f32; 1];
        for _ in 0..10 {
            opt.update_row(0, &[1.0], 0.0, &mut w, &mut b);
        }
        opt.reset();
        let before = w[0];
        opt.update_row(0, &[1.0], 0.0, &mut w, &mut b);
        assert!((w[0] - before + 0.1).abs() < 1e-4);
    }

    #[test]
    fn zero_gradient_is_noop() {
        let mut opt = Adagrad::new(1, 2, 0.1);
        let mut w = vec![1f32, 2.0];
        let mut b = vec![3f32];
        opt.update_row(0, &[0.0, 0.0], 0.0, &mut w, &mut b);
        assert_eq!(w, vec![1.0, 2.0]);
        assert_eq!(b, vec![3.0]);
    }
}
