//! Integration: full training runs through the coordinator on the tiny
//! preset — every method learns (or behaves exactly as the paper predicts),
//! the HLO evaluator agrees with the pure-rust reference evaluator, and
//! runs are deterministic — bit-identically so across every `parallelism`
//! setting of the host-parallel pipeline.

use adv_softmax::eval::{evaluate_reference, Evaluator};
use adv_softmax::prelude::*;
use adv_softmax::train::{BatchGen, BatchMode, BatchSource, SamplerKind};
use std::sync::Arc;

fn registry() -> Registry {
    Registry::open_default().expect("artifacts missing — run `make artifacts` first")
}

fn tiny_splits() -> Splits {
    Splits::synthetic(&SyntheticConfig::preset(DatasetPreset::Tiny))
}

fn short_cfg(method: Method, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new(DatasetPreset::Tiny, method);
    cfg.max_steps = steps;
    cfg.max_seconds = 120.0;
    cfg.eval_points = 512;
    cfg
}

#[test]
fn adversarial_method_learns_tiny() {
    let reg = registry();
    let splits = tiny_splits();
    let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(Method::Adversarial, 600)).unwrap();
    let curve = run.train().unwrap();
    let final_acc = curve.last().unwrap().accuracy;
    assert!(final_acc > 0.85, "adversarial acc {final_acc}");
    assert!(curve.aux_fit_seconds > 0.0);
    // accuracy at the end must beat the tree-alone baseline at step ~0
    let first = curve.points.first().unwrap();
    assert!(final_acc >= first.accuracy);
}

#[test]
fn uniform_and_frequency_learn_tiny() {
    let reg = registry();
    let splits = tiny_splits();
    for method in [Method::Uniform, Method::Frequency] {
        let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(method, 800)).unwrap();
        let curve = run.train().unwrap();
        let acc = curve.best_accuracy();
        assert!(acc > 0.6, "{method} acc {acc}");
        assert_eq!(curve.aux_fit_seconds, 0.0);
    }
}

#[test]
fn pairwise_methods_learn_tiny() {
    let reg = registry();
    let splits = tiny_splits();
    for method in [Method::OneVsEach, Method::AugmentReduce] {
        let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(method, 800)).unwrap();
        let curve = run.train().unwrap();
        let acc = curve.best_accuracy();
        assert!(acc > 0.6, "{method} acc {acc}");
    }
}

#[test]
fn nce_trains_but_ranks_slowly() {
    // The paper's own point (Sec. 5 Baselines): NCE must re-learn what the
    // base distribution captures, so its *ranking* is poor on short
    // budgets even as its loss decreases.
    let reg = registry();
    let splits = tiny_splits();
    let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(Method::Nce, 400)).unwrap();
    let curve = run.train().unwrap();
    let first_loss = curve.points.first().unwrap().train_loss;
    let last_loss = curve.points.last().unwrap().train_loss;
    assert!(last_loss < first_loss, "NCE loss should decrease: {first_loss} -> {last_loss}");
}

#[test]
fn bias_correction_improves_adversarial_predictions() {
    // Ablation A1 as a hard invariant: Eq. 5 correction must help early in
    // training (the tree knows far more than the barely-trained scores).
    let reg = registry();
    let splits = tiny_splits();
    let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(Method::Adversarial, 100)).unwrap();
    for _ in 0..100 {
        run.step_once().unwrap();
    }
    let with = run.evaluate_with(true).unwrap();
    let without = run.evaluate_with(false).unwrap();
    assert!(
        with.accuracy > without.accuracy + 0.05,
        "correction {:.3} vs raw {:.3}",
        with.accuracy,
        without.accuracy
    );
}

#[test]
fn hlo_evaluator_matches_reference_evaluator() {
    let reg = registry();
    let splits = tiny_splits();
    let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(Method::Adversarial, 50)).unwrap();
    for _ in 0..50 {
        run.step_once().unwrap();
    }
    let mut rng = Rng::new(7);
    let eval_set = splits.test.subsample(300, &mut rng); // non-multiple of B: tests padding
    let evaluator = Evaluator::new(&reg).unwrap();
    for corrector in [None, run.aux.as_deref()] {
        let hlo = evaluator.evaluate(&run.params, &eval_set, corrector).unwrap();
        let refr = evaluate_reference(&run.params, &eval_set, corrector);
        assert_eq!(hlo.n, refr.n);
        assert!(
            (hlo.log_likelihood - refr.log_likelihood).abs() < 1e-3,
            "loglik {} vs {}",
            hlo.log_likelihood,
            refr.log_likelihood
        );
        assert!(
            (hlo.accuracy - refr.accuracy).abs() < 1e-9,
            "acc {} vs {}",
            hlo.accuracy,
            refr.accuracy
        );
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    let reg = registry();
    let splits = tiny_splits();
    let mut cfg = short_cfg(Method::Uniform, 60);
    cfg.pipelined = false; // pipelining preserves the stream; keep the test strict anyway
    let run_once = || {
        let mut run = TrainRun::prepare(&reg, &splits, &cfg).unwrap();
        let mut losses = Vec::new();
        for _ in 0..60 {
            losses.push(run.step_once().unwrap());
        }
        losses
    };
    assert_eq!(run_once(), run_once());
}

#[test]
fn pipelined_equals_inline_stream() {
    let reg = registry();
    let splits = tiny_splits();
    let mut cfg = short_cfg(Method::Adversarial, 40);
    let mut losses = Vec::new();
    for pipelined in [false, true] {
        cfg.pipelined = pipelined;
        let mut run = TrainRun::prepare(&reg, &splits, &cfg).unwrap();
        let mut l = Vec::new();
        for _ in 0..40 {
            l.push(run.step_once().unwrap());
        }
        losses.push(l);
    }
    assert_eq!(losses[0], losses[1]);
}

/// The pipeline's core invariant, checked without any artifacts: the batch
/// stream coming out of a [`BatchSource`] is bit-identical for the inline
/// path and for every pipeline worker count.
#[test]
fn batch_stream_identical_across_worker_counts() {
    let splits = tiny_splits();
    let data = Arc::new(splits.train.clone());
    let make_gen = || {
        BatchGen::new(
            data.clone(),
            SamplerKind::Uniform(UniformSampler::new(data.num_classes)),
            BatchMode::NsLike,
            256,
            1.0,
            Rng::new(5),
        )
    };
    let collect = |mut src: BatchSource| -> Vec<(Vec<u32>, Vec<u32>, Vec<f32>, Vec<f32>)> {
        (0..30)
            .map(|_| {
                let b = src.next();
                let row = (b.pos.clone(), b.neg.clone(), b.lpn_p.clone(), b.lpn_n.clone());
                src.recycle(b);
                row
            })
            .collect()
    };
    let inline = collect(BatchSource::inline(make_gen()));
    for workers in [1usize, 2, 3, 4] {
        let gen = make_gen();
        let piped = collect(BatchSource::pipelined(&gen, workers));
        assert_eq!(piped, inline, "workers={workers}");
    }
}

/// Adversarial batches (blocked tree descents) are also stream-stable.
#[test]
fn adversarial_batch_stream_identical_across_worker_counts() {
    let splits = tiny_splits();
    let data = Arc::new(splits.train.clone());
    let tcfg = adv_softmax::config::TreeConfig { aux_dim: 8, ..Default::default() };
    let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 3);
    let adv = Arc::new(adv);
    let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
    let make_gen = || {
        BatchGen::new(
            data.clone(),
            SamplerKind::Adversarial { sampler: adv.clone(), x_proj: x_proj.clone() },
            BatchMode::NsLike,
            256,
            1.0,
            Rng::new(6),
        )
    };
    let collect = |mut src: BatchSource| -> Vec<(Vec<u32>, Vec<u32>, Vec<f32>, Vec<f32>)> {
        (0..20)
            .map(|_| {
                let b = src.next();
                let row = (b.pos.clone(), b.neg.clone(), b.lpn_p.clone(), b.lpn_n.clone());
                src.recycle(b);
                row
            })
            .collect()
    };
    let inline = collect(BatchSource::inline(make_gen()));
    for workers in [2usize, 4] {
        let gen = make_gen();
        assert_eq!(collect(BatchSource::pipelined(&gen, workers)), inline, "workers={workers}");
    }
}

/// End to end: the learning curve (train loss, eval metrics, step ids) is
/// bit-identical between a serial run and a `parallelism = 4` run — the
/// acceptance bar for the host-parallel refactor.
#[test]
fn learning_curve_identical_across_parallelism() {
    let reg = registry();
    let splits = tiny_splits();
    let mut curves: Vec<Vec<(usize, f64, f64, f64)>> = Vec::new();
    for parallelism in [1usize, 4] {
        let mut cfg = short_cfg(Method::Adversarial, 120);
        cfg.eval_every = 40;
        cfg.parallelism = parallelism;
        let mut run = TrainRun::prepare(&reg, &splits, &cfg).unwrap();
        let curve = run.train().unwrap();
        curves.push(
            curve
                .points
                .iter()
                .map(|p| (p.step, p.train_loss, p.log_likelihood, p.accuracy))
                .collect(),
        );
    }
    assert!(!curves[0].is_empty());
    assert_eq!(curves[0], curves[1]);
}

#[test]
fn softmax_method_requires_matching_c() {
    let reg = registry();
    let splits = tiny_splits(); // C=256 != softmax_c=4096
    let cfg = short_cfg(Method::Softmax, 10);
    assert!(TrainRun::prepare(&reg, &splits, &cfg).is_err());
}

#[test]
fn curve_csv_appends() {
    let reg = registry();
    let splits = tiny_splits();
    let mut run = TrainRun::prepare(&reg, &splits, &short_cfg(Method::Uniform, 30)).unwrap();
    let curve = run.train().unwrap();
    let path = std::env::temp_dir().join("adv_softmax_integration_curve.csv");
    std::fs::remove_file(&path).ok();
    curve.append_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("dataset,method,step"));
    assert!(text.lines().count() >= 2);
    std::fs::remove_file(&path).ok();
}
