//! Bit-exact parity of the parallel auxiliary-model fit (PR 2).
//!
//! The whole aux-model construction path — PCA mean/covariance, the
//! projection pass, and the level-synchronous tree fit — must produce the
//! **same bits** at every `parallelism` setting, and oversized aux dims
//! must be rejected when the config is loaded rather than panicking on a
//! fixed-size stack buffer in the sampler hot path of a release build.

use adv_softmax::config::{
    DatasetPreset, Method, RunConfig, SyntheticConfig, TreeConfig, MAX_AUX_DIM,
};
use adv_softmax::data::Splits;
use adv_softmax::linalg::Pca;
use adv_softmax::sampler::AdversarialSampler;
use adv_softmax::tree::fit::{fit_tree, fit_tree_with};
use adv_softmax::utils::{Pool, Rng};

const WORKER_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn tiny_splits() -> Splits {
    let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
    cfg.n_train = 4096;
    Splits::synthetic(&cfg)
}

#[test]
fn tree_fit_bit_identical_across_worker_counts() {
    let splits = tiny_splits();
    let d = &splits.train;
    let k = 8;
    let tcfg = TreeConfig { aux_dim: k, ..Default::default() };
    let pca = Pca::fit(&d.features, d.len(), d.feat_dim, k, 11);
    let x_proj = pca.project_all(&d.features, d.len());
    let mut rng = Rng::new(13);
    let (reference, ref_stats) =
        fit_tree(&x_proj, &d.labels, d.len(), k, d.num_classes, &tcfg, &mut rng);
    for workers in WORKER_COUNTS {
        let pool = Pool::new(workers);
        let mut rng = Rng::new(13);
        let (t, s) =
            fit_tree_with(&x_proj, &d.labels, d.len(), k, d.num_classes, &tcfg, &mut rng, &pool);
        assert_eq!(t.w, reference.w, "w differs at workers={workers}");
        assert_eq!(t.b, reference.b, "b differs at workers={workers}");
        assert_eq!(t.forced, reference.forced, "forced differs at workers={workers}");
        assert_eq!(
            t.label_of_leaf, reference.label_of_leaf,
            "label_of_leaf differs at workers={workers}"
        );
        assert_eq!(
            t.leaf_of_label, reference.leaf_of_label,
            "leaf_of_label differs at workers={workers}"
        );
        assert_eq!(s.nodes_fitted, ref_stats.nodes_fitted, "workers={workers}");
        assert_eq!(s.newton_iters_total, ref_stats.newton_iters_total, "workers={workers}");
        assert_eq!(s.alternations_total, ref_stats.alternations_total, "workers={workers}");
        assert_eq!(s.forced_nodes, ref_stats.forced_nodes, "workers={workers}");
        assert_eq!(s.train_mean_loglik, ref_stats.train_mean_loglik, "workers={workers}");
    }
}

#[test]
fn tree_fit_parity_holds_under_subsampling() {
    // fit_subsample exercises the caller-RNG shuffle before the frontier:
    // per-node streams must still be independent of the worker count
    let splits = tiny_splits();
    let d = &splits.train;
    let k = 6;
    let tcfg = TreeConfig { aux_dim: k, fit_subsample: 1500, ..Default::default() };
    let pca = Pca::fit(&d.features, d.len(), d.feat_dim, k, 3);
    let x_proj = pca.project_all(&d.features, d.len());
    let mut rng = Rng::new(29);
    let (reference, _) =
        fit_tree(&x_proj, &d.labels, d.len(), k, d.num_classes, &tcfg, &mut rng);
    for workers in [2, 7] {
        let mut rng = Rng::new(29);
        let (t, _) = fit_tree_with(
            &x_proj, &d.labels, d.len(), k, d.num_classes, &tcfg, &mut rng,
            &Pool::new(workers),
        );
        assert_eq!(t.w, reference.w, "workers={workers}");
        assert_eq!(t.label_of_leaf, reference.label_of_leaf, "workers={workers}");
    }
}

#[test]
fn pca_fit_bit_identical_across_worker_counts() {
    let splits = tiny_splits();
    let d = &splits.train;
    let reference = Pca::fit(&d.features, d.len(), d.feat_dim, 12, 5);
    for workers in WORKER_COUNTS {
        let p = Pca::fit_with(&d.features, d.len(), d.feat_dim, 12, 5, &Pool::new(workers));
        assert_eq!(p.mean, reference.mean, "mean differs at workers={workers}");
        assert_eq!(
            p.components, reference.components,
            "components differ at workers={workers}"
        );
        assert_eq!(p.proj_bias, reference.proj_bias, "proj_bias differs at workers={workers}");
    }
}

/// PR 4: the pooled power-iteration matvec engages above its dimension
/// floor (128); a wide synthetic feature space must still fit to the
/// exact serial bits at every worker count.
#[test]
fn pca_fit_bit_identical_above_parallel_matvec_floor() {
    let (n, kin) = (700usize, 150usize);
    let mut rng = Rng::new(41);
    // low-rank structure + noise so the components are well-defined
    let dir: Vec<f32> = (0..kin).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
    let data: Vec<f32> = (0..n)
        .flat_map(|_| {
            let a = 3.0 * rng.normal();
            let noise: Vec<f32> = (0..kin).map(|_| 0.3 * rng.normal()).collect();
            dir.iter().zip(noise).map(move |(d, e)| a * d + e).collect::<Vec<f32>>()
        })
        .collect();
    let reference = Pca::fit(&data, n, kin, 4, 9);
    for workers in WORKER_COUNTS {
        let p = Pca::fit_with(&data, n, kin, 4, 9, &Pool::new(workers));
        assert_eq!(p.mean, reference.mean, "mean differs at workers={workers}");
        assert_eq!(p.components, reference.components, "components differ at workers={workers}");
        assert_eq!(p.proj_bias, reference.proj_bias, "proj_bias differs at workers={workers}");
    }
}

#[test]
fn sampler_fit_bit_identical_across_worker_counts() {
    let splits = tiny_splits();
    let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
    let (reference, _) = AdversarialSampler::fit(&splits.train, &tcfg, 21);
    for workers in [2, 3, 7] {
        let (s, _) = AdversarialSampler::fit_with(&splits.train, &tcfg, 21, &Pool::new(workers));
        assert_eq!(s.pca.mean, reference.pca.mean, "workers={workers}");
        assert_eq!(s.pca.components, reference.pca.components, "workers={workers}");
        assert_eq!(s.pca.proj_bias, reference.pca.proj_bias, "workers={workers}");
        assert_eq!(s.tree.w, reference.tree.w, "workers={workers}");
        assert_eq!(s.tree.b, reference.tree.b, "workers={workers}");
        assert_eq!(s.tree.forced, reference.tree.forced, "workers={workers}");
        assert_eq!(
            s.tree.label_of_leaf, reference.tree.label_of_leaf,
            "workers={workers}"
        );
    }
}

#[test]
fn oversized_aux_dim_rejected_at_config_load_not_release_panic() {
    let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
    cfg.tree.aux_dim = MAX_AUX_DIM + 1;
    // config load is the contract: the error arrives here, with a clear
    // message, instead of as a buffer panic inside sample()/log_prob() —
    // which release builds (debug_assert compiled out) used to reach
    let err = RunConfig::from_json(&cfg.to_json());
    assert!(err.is_err(), "aux_dim {} must be rejected", MAX_AUX_DIM + 1);
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("aux_dim"), "error should name the knob: {msg}");

    // the boundary value stays valid
    cfg.tree.aux_dim = MAX_AUX_DIM;
    assert!(RunConfig::from_json(&cfg.to_json()).is_ok());
}
