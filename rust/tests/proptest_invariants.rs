//! Property-based tests on coordinator invariants.
//!
//! The proptest crate is unavailable offline, so this file uses an
//! equivalent in-tree pattern: each property runs against many randomized
//! cases drawn from a seeded generator, and failures report the seed of
//! the offending case so it can be replayed exactly.

use adv_softmax::config::TreeConfig;
use adv_softmax::data::Dataset;
use adv_softmax::linalg::{lse_merge, solve_spd};
use adv_softmax::model::ParamStore;
use adv_softmax::sampler::{FrequencySampler, NoiseSampler, UniformSampler};
use adv_softmax::tree::fit::fit_tree;
use adv_softmax::tree::{BeamScratch, Tree, TreeKernel, PADDING};
use adv_softmax::utils::json::Json;
use adv_softmax::utils::{AliasTable, Pool, Rng};

/// Run `prop` over `cases` random seeds; panic with the seed on failure.
fn for_all_seeds(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xfeed_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(">>> property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_tree_data(rng: &mut Rng) -> (Vec<f32>, Vec<u32>, usize, usize, usize) {
    let c = 2 + rng.below(40); // 2..41 classes, mostly not powers of two
    let k = 1 + rng.below(6);
    let n = 300 + rng.below(700);
    let mut x = vec![0f32; n * k];
    let mut y = vec![0u32; n];
    for i in 0..n {
        let lbl = rng.below(c) as u32;
        y[i] = lbl;
        for j in 0..k {
            x[i * k + j] =
                ((lbl as usize >> j) & 1) as f32 * 2.0 - 1.0 + 0.5 * rng.normal();
        }
    }
    (x, y, n, k, c)
}

/// Tree invariant 1: p_n(·|x) is a normalized distribution over the real
/// labels for any fitted tree and any input.
#[test]
fn prop_tree_normalizes() {
    for_all_seeds(12, |rng| {
        let (x, y, n, k, c) = random_tree_data(rng);
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, rng);
        let mut lps = vec![0f32; c];
        for i in [0usize, n / 2, n - 1] {
            tree.log_prob_all(&x[i * k..(i + 1) * k], &mut lps);
            let total: f64 = lps.iter().map(|&l| (l as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "C={c} k={k}: total {total}");
        }
    });
}

/// Tree invariant 2: leaves and labels are in bijection; padding leaves
/// are never sampled; sample() agrees with log_prob().
#[test]
fn prop_tree_bijection_and_sampling() {
    for_all_seeds(12, |rng| {
        let (x, y, n, k, c) = random_tree_data(rng);
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, rng);
        // bijection
        let mut seen = vec![false; c];
        for &lbl in tree.label_of_leaf.iter().filter(|&&l| l != PADDING) {
            assert!(!seen[lbl as usize]);
            seen[lbl as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // sampling
        let xi = &x[..k];
        for _ in 0..200 {
            let (s, lp) = tree.sample(xi, rng);
            assert!((s as usize) < c);
            let direct = tree.log_prob(xi, s);
            assert!((lp - direct).abs() < 1e-4, "lp {lp} vs {direct}");
        }
    });
}

/// Pin one fitted tree's lane-major kernels bit-identical to the scalar
/// oracle walkers across a set of block sizes (full lane groups, ragged
/// tails, single rows).
fn assert_kernel_parity(tree: &Tree, k: usize, c: usize, rng: &mut Rng) {
    let kern = TreeKernel::build(tree);
    let nn = tree.num_nodes();
    for &m in &[1usize, 7, 8, 64, 129] {
        let x_projs: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        // --- sample_batch vs scalar Tree::sample, same per-draw streams ---
        let base = rng.split(99);
        let mut rngs_block: Vec<Rng> = (0..m).map(|j| base.stream(3, j as u64)).collect();
        let mut rngs_scalar = rngs_block.clone();
        let mut labels = vec![0u32; m];
        let mut logps = vec![0f32; m];
        kern.sample_batch(&x_projs, &mut rngs_block, &mut labels, &mut logps);
        for j in 0..m {
            let (sy, slp) = tree.sample(&x_projs[j * k..(j + 1) * k], &mut rngs_scalar[j]);
            assert_eq!(labels[j], sy, "C={c} k={k} m={m} draw {j}");
            assert_eq!(logps[j].to_bits(), slp.to_bits(), "C={c} k={k} m={m} draw {j}");
            // the private streams were consumed identically
            assert_eq!(rngs_block[j].next_u64(), rngs_scalar[j].next_u64());
        }
        // --- log_prob_batch vs scalar log_prob (sampled + arbitrary ys) ---
        let mut ys = labels.clone();
        for (j, yj) in ys.iter_mut().enumerate() {
            if j % 3 == 0 {
                *yj = (j % c) as u32;
            }
        }
        let mut lp_block = vec![0f32; m];
        kern.log_prob_batch(&x_projs, &ys, &mut lp_block);
        for j in 0..m {
            let direct = tree.log_prob(&x_projs[j * k..(j + 1) * k], ys[j]);
            assert_eq!(lp_block[j].to_bits(), direct.to_bits(), "C={c} k={k} m={m} row {j}");
        }
        // --- batched activation sweep vs scalar node_activations ---
        let mut acts_b = vec![0f32; m * nn];
        kern.node_activations_batch(&x_projs, m, &mut acts_b);
        let mut acts_s = vec![0f32; nn];
        for j in 0..m {
            tree.node_activations(&x_projs[j * k..(j + 1) * k], &mut acts_s);
            assert_eq!(&acts_b[j * nn..(j + 1) * nn], &acts_s[..], "C={c} k={k} m={m} row {j}");
        }
        // --- log_prob_all (activation sweep + prefix) vs scalar log_prob ---
        let mut all = vec![0f32; c];
        tree.log_prob_all(&x_projs[..k], &mut all);
        for (y, &lp) in all.iter().enumerate() {
            let direct = tree.log_prob(&x_projs[..k], y as u32);
            assert_eq!(lp.to_bits(), direct.to_bits(), "C={c} k={k} label {y}");
        }
    }
}

/// Blocked-descent invariant: the `TreeKernel` batch paths agree bit for
/// bit with the retained scalar walkers under the same split per-draw RNG
/// streams — for arbitrary fitted trees (non-power-of-two C, forced
/// padding branches included).
#[test]
fn prop_kernel_descents_match_scalar_oracle() {
    for_all_seeds(8, |rng| {
        let (x, y, n, k, c) = random_tree_data(rng);
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, rng);
        assert_kernel_parity(&tree, k, c, rng);
    });
}

/// Kernel parity across the lane-width grid the ISSUE pins: auxiliary
/// dimensions k ∈ {1, 7, 8, 64} (below/at/above the 4-lane dot chunk and
/// at MAX_AUX_DIM) × padded and power-of-two label counts, with fitted
/// trees so forced chains appear at several depths.
#[test]
fn prop_kernel_parity_k_grid() {
    for (case, &k) in [1usize, 7, 8, 64].iter().enumerate() {
        let mut rng = Rng::new(0xbead_0000 + case as u64);
        for &c in &[5usize, 16, 33] {
            let n = 400;
            let mut x = vec![0f32; n * k];
            let mut y = vec![0u32; n];
            for i in 0..n {
                let lbl = rng.below(c) as u32;
                y[i] = lbl;
                for j in 0..k {
                    x[i * k + j] =
                        ((lbl as usize >> (j % 6)) & 1) as f32 * 2.0 - 1.0 + 0.5 * rng.normal();
                }
            }
            // small Newton budget: the parity property does not depend on
            // fit quality, only on realistic fitted/forced structure
            let cfg = TreeConfig {
                aux_dim: k,
                newton_iters: 3,
                max_alternations: 2,
                ..Default::default()
            };
            let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, &mut rng);
            assert_kernel_parity(&tree, k, c, &mut rng);
        }
    }
}

/// Serving-retrieval invariant: the lane-group beam descent
/// (`beam_topk`) equals the per-prefix scalar oracle (`beam_topk_scalar`)
/// bit for bit — for arbitrary fitted trees (forced chains and padding
/// included), beam widths below/at/above the lane width (ragged staged
/// tails), and the full-coverage beam.
#[test]
fn prop_beam_topk_matches_scalar_oracle() {
    for_all_seeds(8, |rng| {
        let (x, y, n, k, c) = random_tree_data(rng);
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, rng);
        let kern = TreeKernel::build(&tree);
        let (mut s_lane, mut s_scalar) = (BeamScratch::default(), BeamScratch::default());
        let (mut out_lane, mut out_scalar) = (Vec::new(), Vec::new());
        for q in 0..4 {
            let x_proj: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            for &beam in &[1usize, 2, 3, 7, 8, 9, 17, c] {
                kern.beam_topk(&x_proj, beam, &mut out_lane, &mut s_lane);
                kern.beam_topk_scalar(&x_proj, beam, &mut out_scalar, &mut s_scalar);
                assert_eq!(
                    out_lane.len(),
                    out_scalar.len(),
                    "C={c} k={k} beam={beam} query {q}: candidate count"
                );
                for (i, (a, b)) in out_lane.iter().zip(out_scalar.iter()).enumerate() {
                    assert_eq!(a.0, b.0, "C={c} k={k} beam={beam} query {q}: label of cand {i}");
                    assert_eq!(
                        a.1.to_bits(),
                        b.1.to_bits(),
                        "C={c} k={k} beam={beam} query {q}: log q bits of cand {i}"
                    );
                }
            }
        }
    });
}

/// Sharded-scatter invariant: `apply_sparse_par` is bit-identical to the
/// serial scatter (including duplicate-label Adagrad sequencing) for
/// arbitrary shapes, duplicate densities, and worker counts; `gather_par`
/// reads back identically too.
#[test]
fn prop_sharded_gather_scatter_match_serial() {
    for_all_seeds(10, |rng| {
        let c = 2 + rng.below(40);
        let k = 1 + rng.below(16);
        let b = 64 + rng.below(256); // above the parallel threshold
        let labels: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let mut serial = ParamStore::zeros(c, k, 0.1);
        serial.apply_sparse(&labels, &gw, &gb);
        let workers = 2 + rng.below(5);
        let pool = Pool::new(workers);
        let mut par = ParamStore::zeros(c, k, 0.1);
        par.apply_sparse_par(&pool, &labels, &gw, &gb);
        assert_eq!(par.w, serial.w, "C={c} k={k} b={b} workers={workers}");
        assert_eq!(par.b, serial.b);
        let mut w_s = vec![0f32; b * k];
        let mut b_s = vec![0f32; b];
        serial.gather(&labels, &mut w_s, &mut b_s);
        let mut w_p = vec![0f32; b * k];
        let mut b_p = vec![0f32; b];
        par.gather_par(&pool, &labels, &mut w_p, &mut b_p);
        assert_eq!(w_p, w_s);
        assert_eq!(b_p, b_s);
    });
}

/// Row-lease invariant (PR 4): for arbitrary shapes, adversarial pos/neg
/// label overlap between consecutive steps, and any worker count, the
/// eager leased gather (run as a background stage, skipping the in-flight
/// step's rows) followed by the post-scatter patch returns buffers
/// bit-identical to a serial gather performed after the scatter.
#[test]
fn prop_leased_gather_patch_is_bit_identical() {
    for_all_seeds(10, |rng| {
        let c = 2 + rng.below(30); // small C ⇒ heavy forced conflicts
        let k = 1 + rng.below(12);
        let b = 32 + rng.below(200);
        let mut p = ParamStore::zeros(c, k, 0.1);
        // non-trivial starting parameters + accumulators
        let warm: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let wgw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let wgb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        p.apply_sparse(&warm, &wgw, &wgb);
        // step t's update set: half the label space, duplicated
        let cur: Vec<u32> = (0..b).map(|_| rng.below(c.div_ceil(2)) as u32).collect();
        let gw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        // step t+1's labels: biased into the same half ⇒ dense conflicts
        let nxt: Vec<u32> = (0..b)
            .map(|_| {
                if rng.bernoulli(0.7) {
                    rng.below(c.div_ceil(2)) as u32
                } else {
                    rng.below(c) as u32
                }
            })
            .collect();

        // serial reference: scatter then gather
        let mut serial = p.clone();
        serial.apply_sparse(&cur, &gw, &gb);
        let mut w_ref = vec![0f32; b * k];
        let mut b_ref = vec![0f32; b];
        serial.gather(&nxt, &mut w_ref, &mut b_ref);

        // leased protocol at a random worker count
        let workers = 1 + rng.below(6);
        let pool = Pool::new(workers);
        let lease = p.lease_rows(&[&cur]);
        let mut w_out = vec![f32::NAN; b * k]; // every slot must be written
        let mut b_out = vec![f32::NAN; b];
        {
            let w_view = adv_softmax::utils::SharedMut::new(&mut w_out);
            let b_view = adv_softmax::utils::SharedMut::new(&mut b_out);
            let (p_ref, nxt_ref) = (&p, &nxt);
            let shards = pool.stage_shards();
            pool.submit_sharded(move |shard| {
                p_ref.gather_leased_shard(nxt_ref, lease, shards, shard, &w_view, &b_view);
            })
            .join();
        }
        p.apply_sparse_par(&pool, &cur, &gw, &gb);
        let patched = p.patch_leased(&nxt, lease, &mut w_out, &mut b_out);
        let expect = nxt.iter().filter(|&&y| cur.contains(&y)).count();
        assert_eq!(patched, expect, "C={c} k={k} b={b} workers={workers}");
        assert_eq!(w_out, w_ref, "C={c} k={k} b={b} workers={workers}");
        assert_eq!(b_out, b_ref, "C={c} k={k} b={b} workers={workers}");
    });
}

/// Sampler invariant: every sampler's log_prob is consistent with its
/// empirical sampling distribution (KL ≈ 0 on a coarse histogram).
#[test]
fn prop_sampler_logprob_matches_empirical() {
    for_all_seeds(6, |rng| {
        let c = 2 + rng.below(20);
        let n = 2000;
        let k = 3;
        let feats: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let labels: Vec<u32> = (0..n).map(|_| rng.below(c) as u32).collect();
        let data = Dataset::new(feats, labels, k, c);
        let samplers: Vec<Box<dyn NoiseSampler>> = vec![
            Box::new(UniformSampler::new(c)),
            Box::new(FrequencySampler::from_dataset(&data, 1.0).unwrap()),
        ];
        for s in &samplers {
            let draws = 60_000;
            let mut counts = vec![0usize; c];
            for _ in 0..draws {
                counts[s.sample(&[], rng).0 as usize] += 1;
            }
            for lbl in 0..c {
                let p = (s.log_prob(&[], lbl as u32) as f64).exp();
                let emp = counts[lbl] as f64 / draws as f64;
                let tol = 4.0 * (p / draws as f64).sqrt() + 2e-3;
                assert!(
                    (p - emp).abs() < tol,
                    "{}: label {lbl}: p={p:.5} emp={emp:.5}",
                    s.name()
                );
            }
        }
    });
}

/// Alias-table invariant: normalized log-probs and support exactly the
/// nonzero-weight outcomes.
#[test]
fn prop_alias_table_support() {
    for_all_seeds(20, |rng| {
        let n = 1 + rng.below(50);
        let weights: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.2) { 0.0 } else { rng.next_f64() + 0.01 })
            .collect();
        if weights.iter().sum::<f64>() == 0.0 {
            return;
        }
        let t = AliasTable::new(&weights).unwrap();
        let total: f64 = (0..n)
            .map(|i| (t.log_prob(i) as f64).exp())
            .filter(|p| p.is_finite())
            .sum();
        assert!((total - 1.0).abs() < 1e-5);
        for _ in 0..2000 {
            let s = t.sample(rng);
            assert!(weights[s] > 0.0, "sampled zero-weight outcome {s}");
        }
    });
}

/// Alias-table invariant: empirical sampling frequencies match the
/// normalized weights within a Monte-Carlo tolerance, for arbitrary
/// weight vectors (including zero-weight outcomes).
#[test]
fn prop_alias_sampling_frequencies_match_weights() {
    for_all_seeds(8, |rng| {
        let n = 2 + rng.below(24);
        let weights: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.15) { 0.0 } else { rng.next_f64() + 0.05 })
            .collect();
        if weights.iter().sum::<f64>() == 0.0 {
            return;
        }
        let t = AliasTable::new(&weights).unwrap();
        let total: f64 = weights.iter().sum();
        let draws = 80_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            counts[t.sample(rng)] += 1;
        }
        for i in 0..n {
            let p = weights[i] / total;
            let emp = counts[i] as f64 / draws as f64;
            let tol = 4.0 * (p / draws as f64).sqrt() + 2e-3;
            assert!(
                (p - emp).abs() < tol,
                "n={n} outcome {i}: p={p:.5} emp={emp:.5} tol={tol:.5}"
            );
        }
    });
}

/// Alias-table invariant: draws are a pure function of (weights, RNG
/// state) — equal seeds give bit-identical draw streams, and rebuilding
/// the table from the same weights changes nothing.
#[test]
fn prop_alias_equal_seeds_give_identical_draw_streams() {
    for_all_seeds(12, |rng| {
        let n = 1 + rng.below(40);
        let weights: Vec<f64> = (0..n).map(|_| rng.next_f64() + 0.01).collect();
        let t1 = AliasTable::new(&weights).unwrap();
        let t2 = AliasTable::new(&weights).unwrap();
        let seed = rng.next_u64();
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        for step in 0..2000 {
            let (a, b) = (t1.sample(&mut r1), t2.sample(&mut r2));
            assert_eq!(a, b, "seed {seed:#x} diverged at draw {step}");
        }
        // the streams consumed the RNGs identically too
        assert_eq!(r1.next_u64(), r2.next_u64());
        // and log_probs are bit-identical across rebuilds
        for i in 0..n {
            assert_eq!(t1.log_prob(i).to_bits(), t2.log_prob(i).to_bits());
        }
    });
}

/// Streaming LSE merge is associative-equivalent to the global reduction
/// for arbitrary chunkings.
#[test]
fn prop_lse_merge_chunking_invariant() {
    for_all_seeds(30, |rng| {
        let n = 2 + rng.below(200);
        let xs: Vec<f32> = (0..n).map(|_| 10.0 * rng.normal()).collect();
        let gm = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let gs: f32 = xs.iter().map(|x| (x - gm).exp()).sum();
        let global = gm + gs.ln();

        // random chunking
        let (mut m, mut s) = (f32::NEG_INFINITY, 0f32);
        let mut i = 0;
        while i < n {
            let len = 1 + rng.below(n - i);
            let chunk = &xs[i..i + len];
            let cm = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let cs: f32 = chunk.iter().map(|x| (x - cm).exp()).sum();
            let (nm, ns) = lse_merge(m, s, cm, cs);
            m = nm;
            s = ns;
            i += len;
        }
        let streamed = m + s.ln();
        assert!(
            (streamed - global).abs() < 1e-3 * (1.0 + global.abs()),
            "{streamed} vs {global}"
        );
    });
}

/// Gather/scatter invariant: apply_sparse on gathered rows changes exactly
/// the touched rows, and gather reads back what scatter wrote.
#[test]
fn prop_gather_scatter_consistency() {
    for_all_seeds(20, |rng| {
        let c = 4 + rng.below(60);
        let k = 1 + rng.below(16);
        let b = 1 + rng.below(32);
        let mut p = ParamStore::zeros(c, k, 0.1);
        let labels: Vec<u32> = (0..b).map(|_| rng.below(c) as u32).collect();
        let gw: Vec<f32> = (0..b * k).map(|_| rng.normal()).collect();
        let gb: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        p.apply_sparse(&labels, &gw, &gb);
        let touched: std::collections::HashSet<u32> = labels.iter().copied().collect();
        for y in 0..c as u32 {
            let row_nonzero = p.row(y).iter().any(|&v| v != 0.0) || p.b[y as usize] != 0.0;
            if touched.contains(&y) {
                // a row could stay zero only if its gradient was exactly 0
                let any_grad = labels
                    .iter()
                    .enumerate()
                    .filter(|(_, &l)| l == y)
                    .any(|(i, _)| gb[i] != 0.0 || gw[i * k..(i + 1) * k].iter().any(|&g| g != 0.0));
                assert_eq!(row_nonzero, any_grad, "row {y}");
            } else {
                assert!(!row_nonzero, "untouched row {y} changed");
            }
        }
    });
}

/// SPD solver: A x = b residual is tiny for random SPD systems.
#[test]
fn prop_spd_solver_residual() {
    for_all_seeds(25, |rng| {
        let n = 1 + rng.below(12);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal() as f64).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 0.5 } else { 0.0 };
                for l in 0..n {
                    s += m[l * n + i] * m[l * n + j];
                }
                a[i * n + j] = s;
            }
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let x = solve_spd(&a, &b, n).expect("SPD");
        for i in 0..n {
            let ax: f64 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            assert!((ax - b[i]).abs() < 1e-8 * (1.0 + b[i].abs()), "row {i}");
        }
    });
}

/// JSON roundtrip: arbitrary (generated) values survive write->parse.
#[test]
fn prop_json_roundtrip() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.normal() * 100.0).round() as f64 / 4.0),
            3 => {
                let len = rng.below(8);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_all_seeds(50, |rng| {
        let v = gen_value(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text:?}: {e}"));
        assert_eq!(back, v, "text was {text:?}");
    });
}

/// Fault-plan spec roundtrip: for any valid plan, `parse ∘ describe` is
/// the identity — the banner line a chaos run prints is always enough to
/// replay it exactly.
#[test]
fn prop_fault_plan_parse_describe_roundtrip() {
    use adv_softmax::utils::faults::FaultPlan;
    fn gen_rate(rng: &mut Rng) -> f64 {
        rng.below(101) as f64 / 100.0
    }
    for_all_seeds(200, |rng| {
        let mut plan = FaultPlan::disabled(rng.below(1 << 20) as u64);
        plan.panic_rate = gen_rate(rng);
        plan.slow_rate = gen_rate(rng);
        plan.slow_ms = if plan.slow_rate > 0.0 { 1 + rng.below(50) as u64 } else { 0 };
        plan.malform_rate = gen_rate(rng);
        plan.drop_rate = gen_rate(rng);
        plan.delay_rate = gen_rate(rng);
        plan.delay_ms = if plan.delay_rate > 0.0 { 1 + rng.below(50) as u64 } else { 0 };
        plan.dup_rate = gen_rate(rng);
        plan.corrupt_rate = gen_rate(rng);
        let spec = plan.describe();
        let back = FaultPlan::parse(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_eq!(back, plan, "spec was {spec:?}");
    });
}

/// Seq reassignment invariants: for any orphaned seq set and any survivor
/// set, reassignment is a partition (no seq lost, none double-assigned),
/// every assignee is a survivor, and per-survivor load is balanced to
/// within one seq.
#[test]
fn prop_reassignment_partitions_orphans() {
    use adv_softmax::dist::reassign_seqs;
    use std::collections::BTreeSet;
    for_all_seeds(200, |rng| {
        let seqs: BTreeSet<u64> = (0..rng.below(30)).map(|_| rng.below(100) as u64).collect();
        let survivors: BTreeSet<u64> = (0..rng.below(6)).map(|_| rng.below(10) as u64).collect();
        let seqs: Vec<u64> = seqs.into_iter().collect();
        let survivors: Vec<u64> = survivors.into_iter().collect();
        let out = reassign_seqs(&seqs, &survivors);
        if survivors.is_empty() {
            assert!(out.is_empty());
            return;
        }
        assert_eq!(out.iter().map(|&(s, _)| s).collect::<Vec<_>>(), seqs, "seqs lost/reordered");
        let mut load = std::collections::BTreeMap::new();
        for &(_, who) in &out {
            assert!(survivors.contains(&who), "assigned to non-survivor {who}");
            *load.entry(who).or_insert(0usize) += 1;
        }
        if !seqs.is_empty() && seqs.len() >= survivors.len() {
            let min = load.values().min().copied().unwrap_or(0);
            let max = load.values().max().copied().unwrap_or(0);
            assert!(max - min <= 1, "unbalanced: {load:?}");
        }
    });
}
