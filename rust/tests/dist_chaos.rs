//! Seeded chaos for the distributed round protocol: a lossy, delaying,
//! duplicating, corrupting network must slow training down, never change
//! it. Every round still commits exactly once (`RoundStats::accounted`),
//! the final parameters stay bit-identical to a fault-free run, and the
//! whole delivered-frame trace replays identically from the seed.
//!
//! CI's `dist-chaos` job sets `REPRO_FAULTS` to sweep other mixes; without
//! it, a representative built-in plan runs.

use adv_softmax::config::DistConfig;
use adv_softmax::dist::{params_checksum, SimNet};
use adv_softmax::utils::faults::FaultPlan;

/// The mix CI uses when `REPRO_FAULTS` is unset: every frame-level fault
/// kind active at once.
const DEFAULT_PLAN: &str = "seed=20260808,drop=0.08,delay=0.05:120,dup=0.05,corrupt=0.04";

fn plan() -> FaultPlan {
    FaultPlan::from_env()
        .expect("REPRO_FAULTS must parse")
        .unwrap_or_else(|| FaultPlan::parse(DEFAULT_PLAN).unwrap())
}

fn cfg(clients: usize) -> DistConfig {
    DistConfig {
        clients,
        rounds: 3,
        batches_per_round: 6,
        batch_size: 4,
        num_classes: 32,
        feat_dim: 8,
        lr: 0.1,
        seed: 20260808,
        lease_ms: 1000,
        resend_ms: 200,
    }
}

fn run_chaos(m: usize, plan: Option<FaultPlan>) -> SimNet {
    let mut net = SimNet::new(cfg(m), m, plan).unwrap();
    assert!(net.run_to_completion(5000).unwrap(), "chaos run wedged (M={m})");
    net
}

#[test]
fn every_round_commits_exactly_once_under_chaos() {
    let net = run_chaos(2, Some(plan()));
    let stats = net.coord().round_stats();
    assert_eq!(stats.len(), 3, "rounds lost or skipped");
    for r in stats {
        assert!(
            r.accounted(),
            "round {} unaccounted: assigned={} applied={} received={} dup={}",
            r.round,
            r.assigned,
            r.applied,
            r.received,
            r.duplicates
        );
    }
}

#[test]
fn chaos_does_not_change_the_learning_curve() {
    let clean = run_chaos(2, None);
    let chaotic = run_chaos(2, Some(plan()));
    assert_eq!(
        chaotic.coord().loss_bits(),
        clean.coord().loss_bits(),
        "faults changed the loss curve"
    );
    assert_eq!(
        params_checksum(chaotic.coord().params()),
        params_checksum(clean.coord().params()),
        "faults changed the final parameters"
    );
}

#[test]
fn chaos_trace_replays_identically_from_the_seed() {
    let a = run_chaos(2, Some(plan()));
    let b = run_chaos(2, Some(plan()));
    assert!(!a.trace().is_empty());
    assert_eq!(a.trace(), b.trace(), "chaos run is not reproducible");
    assert_eq!(a.coord().stats(), b.coord().stats());
}

#[test]
fn corruption_surfaces_as_typed_errors_not_divergence() {
    // crank corruption up so the typed-error path definitely fires
    let hot = FaultPlan::parse("seed=7,corrupt=0.3").unwrap();
    let net = run_chaos(2, Some(hot));
    assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
    assert!(
        net.coord().stats().malformed > 0 || net.coord().stats().errors_sent > 0,
        "0.3 corruption rate never hit the error path"
    );
    let clean = run_chaos(2, None);
    assert_eq!(net.coord().loss_bits(), clean.coord().loss_bits());
}

#[test]
fn kill_rejoin_under_chaos_still_converges_bit_exactly() {
    let clean = run_chaos(2, None);
    let mut net = SimNet::new(cfg(2), 2, Some(plan())).unwrap();
    // let the run get going, then lose a client and bring it back
    for _ in 0..10 {
        net.step().unwrap();
    }
    net.kill(1);
    // bring it back as a fresh process before the lease lapses, so the
    // rejoin happens while the run is still in flight
    for _ in 0..10 {
        net.step().unwrap();
    }
    net.rejoin(1);
    assert!(net.run_to_completion(5000).unwrap(), "chaos+rejoin run wedged");
    assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
    assert_eq!(net.coord().loss_bits(), clean.coord().loss_bits());
    assert_eq!(params_checksum(net.coord().params()), params_checksum(clean.coord().params()));
}
