//! Bit-exactness of the distributed round protocol (ISSUE 8 acceptance).
//!
//! The invariant under test: the committed parameters after round r are a
//! pure function of `(seed, r)` — independent of how many clients did the
//! work, how the batches were assigned, and whether clients died and
//! rejoined mid-run. M ∈ {1, 2, 4} must produce bit-identical per-round
//! loss curves and a bit-identical final parameter checksum, with and
//! without a mid-run kill/rejoin.

use adv_softmax::config::DistConfig;
use adv_softmax::dist::{params_checksum, Phase, SimNet};

fn cfg(clients: usize) -> DistConfig {
    DistConfig {
        clients,
        rounds: 4,
        batches_per_round: 8,
        batch_size: 4,
        num_classes: 32,
        feat_dim: 8,
        lr: 0.1,
        seed: 20260808,
        lease_ms: 1000,
        resend_ms: 200,
    }
}

/// Run a clean M-client round trip; return (per-round loss bits, final
/// params checksum).
fn run_clean(m: usize) -> (Vec<u64>, u64) {
    let mut net = SimNet::new(cfg(m), m, None).unwrap();
    assert!(net.run_to_completion(1000).unwrap(), "{m}-client run did not finish");
    assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
    (net.coord().loss_bits(), params_checksum(net.coord().params()))
}

#[test]
fn learning_curves_are_bit_identical_across_client_counts() {
    let (curve1, csum1) = run_clean(1);
    assert_eq!(curve1.len(), 4);
    for m in [2usize, 4] {
        let (curve, csum) = run_clean(m);
        assert_eq!(curve, curve1, "loss curve diverged at M={m}");
        assert_eq!(csum, csum1, "final params diverged at M={m}");
    }
}

#[test]
fn losses_are_finite_and_rounds_actually_train() {
    let (curve, _) = run_clean(2);
    let losses: Vec<f64> = curve.iter().map(|&b| f64::from_bits(b)).collect();
    assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0), "losses {losses:?}");
    // round 0 scores against all-zero params: both NS logits are 0, so the
    // mean loss is exactly 2·ln 2 per example
    let expected = 2.0 * std::f64::consts::LN_2;
    assert!((losses[0] - expected).abs() < 1e-9, "round-0 loss {} != 2ln2", losses[0]);
    // later rounds score against updated params, so the loss must move
    assert!(losses[1..].iter().any(|l| (l - expected).abs() > 1e-9), "params never updated");
}

#[test]
fn kill_mid_run_yields_the_same_curve() {
    let (curve1, csum1) = run_clean(1);
    let mut net = SimNet::new(cfg(2), 2, None).unwrap();
    while net.coord().phase() != Phase::Train {
        net.step().unwrap();
    }
    net.kill(1);
    assert!(net.run_to_completion(2000).unwrap(), "survivor did not finish");
    assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
    assert_eq!(net.coord().stats().evictions, 1);
    assert_eq!(net.coord().loss_bits(), curve1, "kill changed the loss curve");
    assert_eq!(params_checksum(net.coord().params()), csum1, "kill changed the params");
}

#[test]
fn kill_and_rejoin_yields_the_same_curve() {
    let (curve1, csum1) = run_clean(1);
    let mut net = SimNet::new(cfg(2), 2, None).unwrap();
    while net.coord().phase() != Phase::Train {
        net.step().unwrap();
    }
    net.kill(0);
    // rejoin while the dead identity's lease is still pending (10 ticks =
    // 500 ms < lease 1000 ms): the fresh process re-enters through Warmup
    // with empty ranges, then inherits the orphans when the old identity
    // is evicted at lease expiry
    for _ in 0..10 {
        net.step().unwrap();
    }
    net.rejoin(0);
    assert!(net.run_to_completion(2000).unwrap(), "run with rejoin did not finish");
    assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
    assert!(net.coord().stats().evictions >= 1);
    assert!(net.coord().stats().joins >= 3, "rejoiner never joined");
    assert_eq!(net.coord().loss_bits(), curve1, "rejoin changed the loss curve");
    assert_eq!(params_checksum(net.coord().params()), csum1, "rejoin changed the params");
}

#[test]
fn four_client_run_distributes_work() {
    let mut net = SimNet::new(cfg(4), 4, None).unwrap();
    assert!(net.run_to_completion(1000).unwrap());
    assert_eq!(net.coord().member_count(), 4);
    for slot in 0..4 {
        let client = net.client(slot).expect("client still alive");
        assert!(client.finished(), "client {slot} never saw shutdown");
        assert!(client.stats().computed > 0, "client {slot} computed nothing");
    }
}

/// End-to-end over the real Unix socket path: `run_coord_socket` +
/// `run_worker_socket` in threads, 2 workers, no faults. The in-memory
/// parity tests pin the math; this pins the transport glue.
#[cfg(unix)]
#[test]
fn socket_round_trip_matches_the_sim() {
    use adv_softmax::dist::{run_coord_socket, run_worker_socket};

    let (curve1, csum1) = run_clean(1);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("repro-dist-parity-{}.sock", std::process::id()));
    let cfg = cfg(2);
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let path = path.clone();
            std::thread::spawn(move || run_worker_socket(&path, &format!("w{i}"), 50, 100))
        })
        .collect();
    let coord = run_coord_socket(&cfg, &path, None).unwrap();
    for w in workers {
        let stats = w.join().unwrap().unwrap();
        assert!(stats.computed > 0);
    }
    assert!(coord.is_done());
    assert!(coord.round_stats().iter().all(|r| r.accounted()));
    assert_eq!(coord.loss_bits(), curve1, "socket run diverged from the sim");
    assert_eq!(params_checksum(coord.params()), csum1);
    assert!(!path.exists(), "socket file not removed on shutdown");
}
