//! Bit-exact parity of the double-buffered step engine (PR 4).
//!
//! The overlapped protocol — step t+1's gather and batch-literal stages
//! running behind step t's execute, with conflict-aware row leasing — must
//! produce **the same bits** as the strictly serial gather → execute →
//! scatter protocol: identical per-step losses and identical parameters
//! (weights, biases, Adagrad accumulators) at every `parallelism` setting,
//! for every batch mode.
//!
//! The PJRT runtime is gated in this environment (vendored host stub), so
//! the device half runs through deterministic host mocks implementing
//! [`StepExecutor`]: a logistic negative-sampling gradient for the NS-like
//! and pairwise modes, and a one-hot-style dense gradient for softmax.
//! Parity only requires the executor to be a pure function of its inputs;
//! using the paper's actual NS gradient additionally lets the tests assert
//! that training under the engine *learns* (loss decreases).

use adv_softmax::config::TreeConfig;
use adv_softmax::data::{Dataset, Splits};
use adv_softmax::model::ParamStore;
use adv_softmax::runtime::{lit_f32, read_f32};
use adv_softmax::sampler::{AdversarialSampler, UniformSampler};
use adv_softmax::train::{
    BatchGen, BatchMode, BatchSource, SamplerKind, StepEngine, StepExecutor,
};
use adv_softmax::utils::{Pool, Rng};
use anyhow::Result;
use std::sync::Arc;

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Host mock of the `ns_grad_` artifact: per example, positive/negative
/// logistic losses on the lpn-adjusted logits u = ξ − log p_n, with the
/// standard row gradients plus λ-regularization. Outputs
/// `[loss(b), gwp(b,k), gbp(b), gwn(b,k), gbn(b)]`.
///
/// Kept in sync by hand with `MockNsExec` in `benches/hot_path.rs` (same
/// math plus a device-latency repeat loop); change the NS input layout in
/// both places.
struct MockNsGrad {
    b: usize,
    k: usize,
}

impl StepExecutor for MockNsGrad {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let (b, k) = (self.b, self.k);
        assert_eq!(inputs.len(), 8, "ns layout: x wp bp wn bn lpn_p lpn_n lam");
        let x = read_f32(&inputs[0])?;
        let wp = read_f32(&inputs[1])?;
        let bp = read_f32(&inputs[2])?;
        let wn = read_f32(&inputs[3])?;
        let bn = read_f32(&inputs[4])?;
        let lpn_p = read_f32(&inputs[5])?;
        let lpn_n = read_f32(&inputs[6])?;
        let lam = read_f32(&inputs[7])?[0];
        let mut loss = vec![0f32; b];
        let mut gwp = vec![0f32; b * k];
        let mut gbp = vec![0f32; b];
        let mut gwn = vec![0f32; b * k];
        let mut gbn = vec![0f32; b];
        for i in 0..b {
            let xi = &x[i * k..(i + 1) * k];
            let xip = wp[i * k..(i + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(w, v)| w * v)
                .sum::<f32>()
                + bp[i];
            let xin = wn[i * k..(i + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(w, v)| w * v)
                .sum::<f32>()
                + bn[i];
            let up = xip - lpn_p[i];
            let un = xin - lpn_n[i];
            // loss_i = softplus(-up) + softplus(un)
            loss[i] = (1.0 + (-up).exp()).ln() + (1.0 + un.exp()).ln();
            let dp = -sigmoid(-up); // d loss / d ξp
            let dn = sigmoid(un); // d loss / d ξn
            gbp[i] = dp;
            gbn[i] = dn;
            for j in 0..k {
                gwp[i * k + j] = dp * xi[j] + lam * wp[i * k + j];
                gwn[i * k + j] = dn * xi[j] + lam * wn[i * k + j];
            }
        }
        Ok(vec![
            lit_f32(&loss, &[b])?,
            lit_f32(&gwp, &[b, k])?,
            lit_f32(&gbp, &[b])?,
            lit_f32(&gwn, &[b, k])?,
            lit_f32(&gbn, &[b])?,
        ])
    }
}

/// Host mock of the `softmax_grad_` artifact's interface with a cheap
/// deterministic gradient: logistic loss on the true row only (the engine
/// parity does not depend on the artifact's exact math, only on the mock
/// being a pure function of its inputs). Outputs `[loss(b), gw(c,k), gb(c)]`.
struct MockSoftmaxGrad {
    b: usize,
    k: usize,
    c: usize,
}

impl StepExecutor for MockSoftmaxGrad {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let (b, k, c) = (self.b, self.k, self.c);
        assert_eq!(inputs.len(), 5, "softmax layout: x w b y lam");
        let x = read_f32(&inputs[0])?;
        let w = read_f32(&inputs[1])?;
        let bias = read_f32(&inputs[2])?;
        let y = adv_softmax::runtime::read_i32(&inputs[3])?;
        let lam = read_f32(&inputs[4])?[0];
        let mut loss = vec![0f32; b];
        let mut gw = vec![0f32; c * k];
        let mut gb = vec![0f32; c];
        for i in 0..b {
            let yi = y[i] as usize;
            let xi = &x[i * k..(i + 1) * k];
            let s = w[yi * k..(yi + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(a, v)| a * v)
                .sum::<f32>()
                + bias[yi];
            loss[i] = (1.0 + (-s).exp()).ln();
            let d = -sigmoid(-s);
            gb[yi] += d;
            for j in 0..k {
                gw[yi * k + j] += d * xi[j];
            }
        }
        for (g, wv) in gw.iter_mut().zip(w.iter()) {
            *g += lam * wv;
        }
        Ok(vec![lit_f32(&loss, &[b])?, lit_f32(&gw, &[c, k])?, lit_f32(&gb, &[c])?])
    }
}

const B: usize = 128;

fn tiny_data() -> Arc<Dataset> {
    let mut cfg =
        adv_softmax::config::SyntheticConfig::preset(adv_softmax::config::DatasetPreset::Tiny);
    cfg.n_train = 2048;
    Arc::new(Splits::synthetic(&cfg).train)
}

/// Run `steps` engine steps and return (losses, final params).
#[allow(clippy::too_many_arguments)]
fn run_engine(
    data: &Arc<Dataset>,
    sampler: SamplerKind,
    mode: BatchMode,
    exec: &dyn StepExecutor,
    steps: usize,
    workers: usize,
    overlap: bool,
    pipelined: bool,
) -> (Vec<f64>, ParamStore) {
    let pool = Pool::new(workers);
    let gen = BatchGen::new(data.clone(), sampler, mode, B, 1.0, Rng::new(11));
    let mut source = if pipelined && mode != BatchMode::Softmax {
        BatchSource::pipelined(&gen, workers.min(4))
    } else {
        BatchSource::inline(gen)
    };
    let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
    let mut engine = StepEngine::new(mode, B, data.feat_dim, 1e-3, overlap);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(engine.step(exec, &mut params, &pool, &mut source).unwrap());
    }
    if overlap && mode != BatchMode::Softmax {
        assert_eq!(engine.steps_overlapped, steps as u64, "overlap must actually engage");
    }
    (losses, params)
}

fn uniform_sampler(data: &Arc<Dataset>) -> SamplerKind {
    SamplerKind::Uniform(UniformSampler::new(data.num_classes))
}

/// The PR 4 acceptance bar, host-side: losses and parameters bit-identical
/// across {overlap on, off} × workers {1, 2, 7} for the uniform sampler.
#[test]
fn ns_learning_curve_bit_identical_overlap_x_workers() {
    let data = tiny_data();
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 40;
    let (ref_losses, ref_params) =
        run_engine(&data, uniform_sampler(&data), BatchMode::NsLike, &exec, steps, 1, false, false);
    // sanity: the engine actually trains under the mock gradient
    let head: f64 = ref_losses[..5].iter().sum();
    let tail: f64 = ref_losses[steps - 5..].iter().sum();
    assert!(tail < head, "loss should decrease: head {head} tail {tail}");
    for overlap in [false, true] {
        for workers in [1usize, 2, 7] {
            let (losses, params) = run_engine(
                &data,
                uniform_sampler(&data),
                BatchMode::NsLike,
                &exec,
                steps,
                workers,
                overlap,
                true,
            );
            assert_eq!(losses, ref_losses, "overlap={overlap} workers={workers}");
            assert_eq!(params.w, ref_params.w, "overlap={overlap} workers={workers}");
            assert_eq!(params.b, ref_params.b, "overlap={overlap} workers={workers}");
        }
    }
}

/// Same bar for the adversarial sampler: tree-descent negatives mean
/// pos/neg label sets that collide across consecutive batches (the lease
/// map earns its keep), and the lpn literals ride the background stage.
#[test]
fn adversarial_learning_curve_bit_identical_overlap_x_workers() {
    let data = tiny_data();
    let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
    let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 3);
    let adv = Arc::new(adv);
    let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
    let make_sampler =
        || SamplerKind::Adversarial { sampler: adv.clone(), x_proj: x_proj.clone() };
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 30;
    let (ref_losses, ref_params) =
        run_engine(&data, make_sampler(), BatchMode::NsLike, &exec, steps, 1, false, false);
    for overlap in [false, true] {
        for workers in [2usize, 7] {
            let (losses, params) = run_engine(
                &data,
                make_sampler(),
                BatchMode::NsLike,
                &exec,
                steps,
                workers,
                overlap,
                true,
            );
            assert_eq!(losses, ref_losses, "overlap={overlap} workers={workers}");
            assert_eq!(params.w, ref_params.w, "overlap={overlap} workers={workers}");
            assert_eq!(params.b, ref_params.b, "overlap={overlap} workers={workers}");
        }
    }
}

/// Softmax always runs the serial protocol (every row conflicts with the
/// dense update); requesting overlap must be a byte-level no-op.
#[test]
fn softmax_ignores_overlap_bit_identically() {
    let data = tiny_data();
    let exec = MockSoftmaxGrad { b: B, k: data.feat_dim, c: data.num_classes };
    let steps = 15;
    let (ref_losses, ref_params) = run_engine(
        &data,
        uniform_sampler(&data),
        BatchMode::Softmax,
        &exec,
        steps,
        1,
        false,
        false,
    );
    for workers in [2usize, 7] {
        let (losses, params) = run_engine(
            &data,
            uniform_sampler(&data),
            BatchMode::Softmax,
            &exec,
            steps,
            workers,
            true,
            false,
        );
        assert_eq!(losses, ref_losses, "workers={workers}");
        assert_eq!(params.w, ref_params.w, "workers={workers}");
        assert_eq!(params.b, ref_params.b, "workers={workers}");
    }
}

/// Executor wrapper that fails exactly one call (coordinator-thread only,
/// hence the plain `Cell` counter).
struct FailOnce<'a> {
    inner: &'a dyn StepExecutor,
    fail_call: usize,
    calls: std::cell::Cell<usize>,
}

impl StepExecutor for FailOnce<'_> {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let n = self.calls.get();
        self.calls.set(n + 1);
        if n == self.fail_call {
            anyhow::bail!("injected transient executor failure");
        }
        self.inner.run_step(inputs)
    }
}

/// Transient-failure contract: an executor error at step t loses batch t
/// (serial semantics) and the overlapped engine hands its prefetched
/// batch t+1 back as pending — a caller that swallows the error and
/// keeps stepping gets the exact serial-resume stream, losses and bits.
#[test]
fn transient_executor_error_resumes_on_serial_stream() {
    let data = tiny_data();
    let ns = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 12;
    let run = |overlap: bool, workers: usize| -> (Vec<f64>, ParamStore) {
        let exec = FailOnce { inner: &ns, fail_call: 5, calls: std::cell::Cell::new(0) };
        let pool = Pool::new(workers);
        let gen = BatchGen::new(
            data.clone(),
            uniform_sampler(&data),
            BatchMode::NsLike,
            B,
            1.0,
            Rng::new(33),
        );
        let mut source = BatchSource::inline(gen);
        let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
        let mut engine = StepEngine::new(BatchMode::NsLike, B, data.feat_dim, 1e-3, overlap);
        let mut losses = Vec::new();
        let mut errors = 0usize;
        for _ in 0..steps {
            match engine.step(&exec, &mut params, &pool, &mut source) {
                Ok(l) => losses.push(l),
                Err(_) => errors += 1,
            }
        }
        assert_eq!(errors, 1, "exactly one injected failure must surface");
        (losses, params)
    };
    let (ref_losses, ref_params) = run(false, 1);
    for workers in [2usize, 7] {
        let (losses, params) = run(true, workers);
        assert_eq!(losses, ref_losses, "workers={workers}");
        assert_eq!(params.w, ref_params.w, "workers={workers}");
        assert_eq!(params.b, ref_params.b, "workers={workers}");
    }
}

/// The invalidation contract: editing the parameters out-of-band between
/// overlapped steps and calling `invalidate_prefetch` forces the engine to
/// re-gather the prefetched slot, reproducing the serial protocol (which
/// naturally gathers after the edit) bit for bit. Without the invalidation
/// the prefetched rows would be pre-edit — this is the staleness hazard
/// the API documents.
#[test]
fn external_param_edit_with_invalidate_is_bit_exact() {
    let data = tiny_data();
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 14;
    let run = |overlap: bool, workers: usize| -> (Vec<f64>, ParamStore) {
        let pool = Pool::new(workers);
        let gen = BatchGen::new(
            data.clone(),
            uniform_sampler(&data),
            BatchMode::NsLike,
            B,
            1.0,
            Rng::new(21),
        );
        let mut source = BatchSource::inline(gen);
        let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
        let mut engine = StepEngine::new(BatchMode::NsLike, B, data.feat_dim, 1e-3, overlap);
        let mut losses = Vec::new();
        for t in 0..steps {
            losses.push(engine.step(&exec, &mut params, &pool, &mut source).unwrap());
            if t == 5 {
                // out-of-band parameter surgery between steps; every row
                // is a candidate for the next batches' gathers
                for v in params.w.iter_mut().step_by(17) {
                    *v += 0.25;
                }
                params.b[1] -= 0.5;
                engine.invalidate_prefetch();
            }
        }
        (losses, params)
    };
    let (ref_losses, ref_params) = run(false, 1);
    for workers in [2usize, 7] {
        let (losses, params) = run(true, workers);
        assert_eq!(losses, ref_losses, "workers={workers}");
        assert_eq!(params.w, ref_params.w, "workers={workers}");
        assert_eq!(params.b, ref_params.b, "workers={workers}");
    }
}
