//! Bit-exact parity of the ring-buffered step engine across pipeline
//! depths (PR 4: double buffering; PR 10: the three-deep execute
//! pipeline).
//!
//! The overlapped protocols — step t+1's gather and batch-literal stages
//! running behind step t's execute at depth 2, plus the dedicated execute
//! thread and the split remainder/conflict scatter at depth 3 — must
//! produce **the same bits** as the strictly serial gather → execute →
//! scatter protocol: identical per-step losses and identical parameters
//! (weights, biases, Adagrad accumulators) at every `parallelism`
//! setting, for every batch mode.
//!
//! The PJRT runtime is gated in this environment (vendored host stub), so
//! the device half runs through deterministic host mocks implementing
//! [`StepExecutor`]: a logistic negative-sampling gradient for the NS-like
//! and pairwise modes, and a one-hot-style dense gradient for softmax.
//! Parity only requires the executor to be a pure function of its inputs;
//! using the paper's actual NS gradient additionally lets the tests assert
//! that training under the engine *learns* (loss decreases).

use adv_softmax::config::TreeConfig;
use adv_softmax::data::{Dataset, Splits};
use adv_softmax::model::ParamStore;
use adv_softmax::runtime::{lit_f32, read_f32};
use adv_softmax::sampler::{AdversarialSampler, UniformSampler};
use adv_softmax::train::{
    BatchGen, BatchMode, BatchSource, SamplerKind, StepEngine, StepExecutor,
};
use adv_softmax::utils::{Pool, Rng};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Host mock of the `ns_grad_` artifact: per example, positive/negative
/// logistic losses on the lpn-adjusted logits u = ξ − log p_n, with the
/// standard row gradients plus λ-regularization. Outputs
/// `[loss(b), gwp(b,k), gbp(b), gwn(b,k), gbn(b)]`.
///
/// Kept in sync by hand with `MockNsExec` in `benches/hot_path.rs` (same
/// math plus a device-latency repeat loop); change the NS input layout in
/// both places.
struct MockNsGrad {
    b: usize,
    k: usize,
}

impl StepExecutor for MockNsGrad {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let (b, k) = (self.b, self.k);
        assert_eq!(inputs.len(), 8, "ns layout: x wp bp wn bn lpn_p lpn_n lam");
        let x = read_f32(&inputs[0])?;
        let wp = read_f32(&inputs[1])?;
        let bp = read_f32(&inputs[2])?;
        let wn = read_f32(&inputs[3])?;
        let bn = read_f32(&inputs[4])?;
        let lpn_p = read_f32(&inputs[5])?;
        let lpn_n = read_f32(&inputs[6])?;
        let lam = read_f32(&inputs[7])?[0];
        let mut loss = vec![0f32; b];
        let mut gwp = vec![0f32; b * k];
        let mut gbp = vec![0f32; b];
        let mut gwn = vec![0f32; b * k];
        let mut gbn = vec![0f32; b];
        for i in 0..b {
            let xi = &x[i * k..(i + 1) * k];
            let xip = wp[i * k..(i + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(w, v)| w * v)
                .sum::<f32>()
                + bp[i];
            let xin = wn[i * k..(i + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(w, v)| w * v)
                .sum::<f32>()
                + bn[i];
            let up = xip - lpn_p[i];
            let un = xin - lpn_n[i];
            // loss_i = softplus(-up) + softplus(un)
            loss[i] = (1.0 + (-up).exp()).ln() + (1.0 + un.exp()).ln();
            let dp = -sigmoid(-up); // d loss / d ξp
            let dn = sigmoid(un); // d loss / d ξn
            gbp[i] = dp;
            gbn[i] = dn;
            for j in 0..k {
                gwp[i * k + j] = dp * xi[j] + lam * wp[i * k + j];
                gwn[i * k + j] = dn * xi[j] + lam * wn[i * k + j];
            }
        }
        Ok(vec![
            lit_f32(&loss, &[b])?,
            lit_f32(&gwp, &[b, k])?,
            lit_f32(&gbp, &[b])?,
            lit_f32(&gwn, &[b, k])?,
            lit_f32(&gbn, &[b])?,
        ])
    }
}

/// Host mock of the `softmax_grad_` artifact's interface with a cheap
/// deterministic gradient: logistic loss on the true row only (the engine
/// parity does not depend on the artifact's exact math, only on the mock
/// being a pure function of its inputs). Outputs `[loss(b), gw(c,k), gb(c)]`.
struct MockSoftmaxGrad {
    b: usize,
    k: usize,
    c: usize,
}

impl StepExecutor for MockSoftmaxGrad {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let (b, k, c) = (self.b, self.k, self.c);
        assert_eq!(inputs.len(), 5, "softmax layout: x w b y lam");
        let x = read_f32(&inputs[0])?;
        let w = read_f32(&inputs[1])?;
        let bias = read_f32(&inputs[2])?;
        let y = adv_softmax::runtime::read_i32(&inputs[3])?;
        let lam = read_f32(&inputs[4])?[0];
        let mut loss = vec![0f32; b];
        let mut gw = vec![0f32; c * k];
        let mut gb = vec![0f32; c];
        for i in 0..b {
            let yi = y[i] as usize;
            let xi = &x[i * k..(i + 1) * k];
            let s = w[yi * k..(yi + 1) * k]
                .iter()
                .zip(xi.iter())
                .map(|(a, v)| a * v)
                .sum::<f32>()
                + bias[yi];
            loss[i] = (1.0 + (-s).exp()).ln();
            let d = -sigmoid(-s);
            gb[yi] += d;
            for j in 0..k {
                gw[yi * k + j] += d * xi[j];
            }
        }
        for (g, wv) in gw.iter_mut().zip(w.iter()) {
            *g += lam * wv;
        }
        Ok(vec![lit_f32(&loss, &[b])?, lit_f32(&gw, &[c, k])?, lit_f32(&gb, &[c])?])
    }
}

const B: usize = 128;

fn tiny_data() -> Arc<Dataset> {
    let mut cfg =
        adv_softmax::config::SyntheticConfig::preset(adv_softmax::config::DatasetPreset::Tiny);
    cfg.n_train = 2048;
    Arc::new(Splits::synthetic(&cfg).train)
}

/// Run `steps` engine steps at the given pipeline depth and return
/// (losses, final params). Asserts the requested protocol actually
/// engaged (every step counted under the depth's counter).
#[allow(clippy::too_many_arguments)]
fn run_engine(
    data: &Arc<Dataset>,
    sampler: SamplerKind,
    mode: BatchMode,
    exec: &dyn StepExecutor,
    steps: usize,
    workers: usize,
    depth: usize,
    pipelined_source: bool,
) -> (Vec<f64>, ParamStore) {
    let pool = Pool::new(workers);
    let gen = BatchGen::new(data.clone(), sampler, mode, B, 1.0, Rng::new(11));
    let mut source = if pipelined_source && mode != BatchMode::Softmax {
        BatchSource::pipelined(&gen, workers.min(4))
    } else {
        BatchSource::inline(gen)
    };
    let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
    let mut engine = StepEngine::new(mode, B, data.feat_dim, 1e-3, depth);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(engine.step(exec, &mut params, &pool, &mut source).unwrap());
    }
    if mode != BatchMode::Softmax {
        match depth {
            2 => assert_eq!(
                engine.steps_overlapped, steps as u64,
                "depth 2 must actually engage"
            ),
            3 => assert_eq!(
                engine.steps_pipelined, steps as u64,
                "depth 3 must actually engage"
            ),
            _ => {
                assert_eq!(engine.steps_overlapped, 0);
                assert_eq!(engine.steps_pipelined, 0);
            }
        }
    }
    (losses, params)
}

fn uniform_sampler(data: &Arc<Dataset>) -> SamplerKind {
    SamplerKind::Uniform(UniformSampler::new(data.num_classes))
}

/// The acceptance bar, host-side: losses and parameters bit-identical
/// across depth {1, 2, 3} × workers {1, 2, 7} for the uniform sampler.
#[test]
fn ns_learning_curve_bit_identical_depth_x_workers() {
    let data = tiny_data();
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 40;
    let (ref_losses, ref_params) =
        run_engine(&data, uniform_sampler(&data), BatchMode::NsLike, &exec, steps, 1, 1, false);
    // sanity: the engine actually trains under the mock gradient
    let head: f64 = ref_losses[..5].iter().sum();
    let tail: f64 = ref_losses[steps - 5..].iter().sum();
    assert!(tail < head, "loss should decrease: head {head} tail {tail}");
    for depth in [1usize, 2, 3] {
        for workers in [1usize, 2, 7] {
            let (losses, params) = run_engine(
                &data,
                uniform_sampler(&data),
                BatchMode::NsLike,
                &exec,
                steps,
                workers,
                depth,
                true,
            );
            assert_eq!(losses, ref_losses, "depth={depth} workers={workers}");
            assert_eq!(params.w, ref_params.w, "depth={depth} workers={workers}");
            assert_eq!(params.b, ref_params.b, "depth={depth} workers={workers}");
        }
    }
}

/// Same bar for the adversarial sampler: tree-descent negatives mean
/// pos/neg label sets that collide across consecutive batches (the lease
/// map — and at depth 3 the two-lease split scatter — earns its keep),
/// and the lpn literals ride the background stage.
#[test]
fn adversarial_learning_curve_bit_identical_depth_x_workers() {
    let data = tiny_data();
    let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
    let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 3);
    let adv = Arc::new(adv);
    let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
    let make_sampler =
        || SamplerKind::Adversarial { sampler: adv.clone(), x_proj: x_proj.clone() };
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 30;
    let (ref_losses, ref_params) =
        run_engine(&data, make_sampler(), BatchMode::NsLike, &exec, steps, 1, 1, false);
    for depth in [2usize, 3] {
        for workers in [1usize, 2, 7] {
            let (losses, params) = run_engine(
                &data,
                make_sampler(),
                BatchMode::NsLike,
                &exec,
                steps,
                workers,
                depth,
                true,
            );
            assert_eq!(losses, ref_losses, "depth={depth} workers={workers}");
            assert_eq!(params.w, ref_params.w, "depth={depth} workers={workers}");
            assert_eq!(params.b, ref_params.b, "depth={depth} workers={workers}");
        }
    }
}

/// Softmax always runs the serial protocol (every row conflicts with the
/// dense update); requesting depth 2 or 3 must be a byte-level no-op.
#[test]
fn softmax_ignores_depth_bit_identically() {
    let data = tiny_data();
    let exec = MockSoftmaxGrad { b: B, k: data.feat_dim, c: data.num_classes };
    let steps = 15;
    let (ref_losses, ref_params) = run_engine(
        &data,
        uniform_sampler(&data),
        BatchMode::Softmax,
        &exec,
        steps,
        1,
        1,
        false,
    );
    for depth in [2usize, 3] {
        for workers in [2usize, 7] {
            let (losses, params) = run_engine(
                &data,
                uniform_sampler(&data),
                BatchMode::Softmax,
                &exec,
                steps,
                workers,
                depth,
                false,
            );
            assert_eq!(losses, ref_losses, "depth={depth} workers={workers}");
            assert_eq!(params.w, ref_params.w, "depth={depth} workers={workers}");
            assert_eq!(params.b, ref_params.b, "depth={depth} workers={workers}");
        }
    }
}

/// Executor wrapper that fails exactly one call. Atomic counter: at depth
/// 3 the engine calls the executor from its dedicated execute thread
/// (`StepExecutor` is `Sync`).
struct FailOnce<'a> {
    inner: &'a dyn StepExecutor,
    fail_call: usize,
    calls: AtomicUsize,
}

impl StepExecutor for FailOnce<'_> {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if n == self.fail_call {
            anyhow::bail!("injected transient executor failure");
        }
        self.inner.run_step(inputs)
    }
}

/// Transient-failure contract: an executor error at step t loses batch t
/// (serial semantics) and the engine hands its prefetched batch t+1 back
/// as pending — a caller that swallows the error and keeps stepping gets
/// the exact serial-resume stream, losses and bits. At depth 3 this
/// additionally pins that the failed step's conflict scatter never lands
/// while the *previous* step's remainder scatter does: a failed step
/// loses only its own batch.
#[test]
fn transient_executor_error_resumes_on_serial_stream() {
    let data = tiny_data();
    let ns = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 12;
    let run = |depth: usize, workers: usize| -> (Vec<f64>, ParamStore) {
        let exec = FailOnce { inner: &ns, fail_call: 5, calls: AtomicUsize::new(0) };
        let pool = Pool::new(workers);
        let gen = BatchGen::new(
            data.clone(),
            uniform_sampler(&data),
            BatchMode::NsLike,
            B,
            1.0,
            Rng::new(33),
        );
        let mut source = BatchSource::inline(gen);
        let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
        let mut engine = StepEngine::new(BatchMode::NsLike, B, data.feat_dim, 1e-3, depth);
        let mut losses = Vec::new();
        let mut errors = 0usize;
        for _ in 0..steps {
            match engine.step(&exec, &mut params, &pool, &mut source) {
                Ok(l) => losses.push(l),
                Err(_) => errors += 1,
            }
        }
        assert_eq!(errors, 1, "exactly one injected failure must surface");
        (losses, params)
    };
    let (ref_losses, ref_params) = run(1, 1);
    for depth in [2usize, 3] {
        for workers in [2usize, 7] {
            let (losses, params) = run(depth, workers);
            assert_eq!(losses, ref_losses, "depth={depth} workers={workers}");
            assert_eq!(params.w, ref_params.w, "depth={depth} workers={workers}");
            assert_eq!(params.b, ref_params.b, "depth={depth} workers={workers}");
        }
    }
}

/// The invalidation contract: editing the parameters out-of-band between
/// steps and calling `invalidate_prefetch` forces the engine to re-gather
/// the prefetched slot, reproducing the serial protocol (which naturally
/// gathers after the edit) bit for bit. At depth 3 the invalidation must
/// additionally land the drained step's pending remainder scatter *before*
/// the caller's edit is observed — without it the parameters would not
/// even be serial-consistent at the edit point.
#[test]
fn external_param_edit_with_invalidate_is_bit_exact() {
    let data = tiny_data();
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    let steps = 14;
    let run = |depth: usize, workers: usize| -> (Vec<f64>, ParamStore) {
        let pool = Pool::new(workers);
        let gen = BatchGen::new(
            data.clone(),
            uniform_sampler(&data),
            BatchMode::NsLike,
            B,
            1.0,
            Rng::new(21),
        );
        let mut source = BatchSource::inline(gen);
        let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
        let mut engine = StepEngine::new(BatchMode::NsLike, B, data.feat_dim, 1e-3, depth);
        let mut losses = Vec::new();
        for t in 0..steps {
            losses.push(engine.step(&exec, &mut params, &pool, &mut source).unwrap());
            if t == 5 {
                engine.invalidate_prefetch(&mut params);
                // out-of-band parameter surgery between steps; every row
                // is a candidate for the next batches' gathers
                for v in params.w.iter_mut().step_by(17) {
                    *v += 0.25;
                }
                params.b[1] -= 0.5;
            }
        }
        (losses, params)
    };
    let (ref_losses, ref_params) = run(1, 1);
    for depth in [2usize, 3] {
        for workers in [2usize, 7] {
            let (losses, params) = run(depth, workers);
            assert_eq!(losses, ref_losses, "depth={depth} workers={workers}");
            assert_eq!(params.w, ref_params.w, "depth={depth} workers={workers}");
            assert_eq!(params.b, ref_params.b, "depth={depth} workers={workers}");
        }
    }
}

/// The buffer-donation claim: once the three-slot ring is warm, pipelined
/// steps refill donated literals in place — the fresh-allocation counter
/// must freeze. (The depth-2 path shares the plumbing and is covered by
/// the same assertion.)
#[test]
fn steady_state_execute_is_literal_allocation_free() {
    let data = tiny_data();
    let exec = MockNsGrad { b: B, k: data.feat_dim };
    for depth in [2usize, 3] {
        let pool = Pool::new(2);
        let gen = BatchGen::new(
            data.clone(),
            uniform_sampler(&data),
            BatchMode::NsLike,
            B,
            1.0,
            Rng::new(7),
        );
        let mut source = BatchSource::inline(gen);
        let mut params = ParamStore::zeros(data.num_classes, data.feat_dim, 0.05);
        let mut engine = StepEngine::new(BatchMode::NsLike, B, data.feat_dim, 1e-3, depth);
        // warmup: every ring slot seals its first literal set fresh
        for _ in 0..depth + 1 {
            engine.step(&exec, &mut params, &pool, &mut source).unwrap();
        }
        let warm = engine.lit_allocs();
        assert!(warm > 0, "warmup must have allocated the ring's literals");
        for _ in 0..10 {
            engine.step(&exec, &mut params, &pool, &mut source).unwrap();
        }
        assert_eq!(
            engine.lit_allocs(),
            warm,
            "depth={depth}: steady-state steps must refill, not allocate"
        );
    }
}
