//! Chaos acceptance for the serving daemon: under a seeded fault plan the
//! daemon never crashes or hangs, answers every submitted request exactly
//! once with a typed response, serves degraded responses bit-exactly at
//! the tagged beam width, reproduces the same response trace run over
//! run, and recovers bit-identically once faults stop.
//!
//! The CI chaos job overrides the plan through `REPRO_FAULTS`; every
//! assertion here is plan-agnostic (response *shapes* and accounting, not
//! fault counts), so any valid plan must pass.

use adv_softmax::config::{DaemonConfig, DatasetPreset, ServeConfig, SyntheticConfig, TreeConfig};
use adv_softmax::data::{Dataset, Splits};
use adv_softmax::sampler::AdversarialSampler;
use adv_softmax::serve::daemon::{self, Daemon, ManualClock, RealClock, ResponseKind};
use adv_softmax::utils::faults::FaultPlan;
use adv_softmax::serve::{Predictor, ServingModel, TopK};
use std::sync::{Arc, OnceLock};

/// Shared fixture (mirrors `tests/serve_parity.rs`): centroid classifier
/// rows plus a genuinely fitted auxiliary tree over the tiny preset
/// (C = 256, K = 64), built once per test binary.
fn centroid_model() -> &'static (ServingModel, Dataset) {
    static MODEL: OnceLock<(ServingModel, Dataset)> = OnceLock::new();
    MODEL.get_or_init(|| {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 4096;
        cfg.n_test = 512;
        let splits = Splits::synthetic(&cfg);
        let train = &splits.train;
        let (c, k) = (train.num_classes, train.feat_dim);
        let mut w = vec![0f32; c * k];
        let mut counts = vec![0f32; c];
        for i in 0..train.len() {
            let y = train.y(i) as usize;
            counts[y] += 1.0;
            for (wv, xv) in w[y * k..(y + 1) * k].iter_mut().zip(train.x(i).iter()) {
                *wv += *xv;
            }
        }
        for y in 0..c {
            if counts[y] > 0.0 {
                let scale = 4.0 / counts[y];
                for wv in w[y * k..(y + 1) * k].iter_mut() {
                    *wv *= scale;
                }
            }
        }
        let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
        let (aux, _) = AdversarialSampler::fit(train, &tcfg, 5);
        let model = ServingModel {
            num_classes: c,
            feat_dim: k,
            w,
            b: vec![0f32; c],
            aux: Some(aux),
            correct_bias: true,
        };
        (model, splits.test)
    })
}

fn arc_model() -> Arc<ServingModel> {
    Arc::new(centroid_model().0.clone())
}

/// Test query i as a protocol line (float `Display` round-trips exactly,
/// so the parsed query is bit-identical to the dataset row).
fn query_line(test: &Dataset, i: usize) -> String {
    test.x(i % test.len())
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(" ")
}

fn assert_topk_bit_eq(got: &TopK, want: &TopK, ctx: &str) {
    assert_eq!(got.labels, want.labels, "{ctx}: labels");
    let gb: Vec<u32> = got.scores.iter().map(|s| s.to_bits()).collect();
    let wb: Vec<u32> = want.scores.iter().map(|s| s.to_bits()).collect();
    assert_eq!(gb, wb, "{ctx}: score bits");
}

/// Reference predictions at a given beam width, computed one query at a
/// time — per the serving determinism contract this IS the fault-free
/// daemon output for that beam.
fn oracle_at_beam(beam: usize) -> Predictor<'static> {
    let (model, _) = centroid_model();
    Predictor::new(model, ServeConfig { beam, ..Default::default() }).unwrap()
}

/// Sustained overload steps the beam down the configured ladder, tags the
/// responses, serves them bit-exactly at the reduced width, and restores
/// the full beam as the queue drains.
#[test]
fn degradation_steps_down_ladder_bit_exactly_and_restores() {
    let (model, test) = centroid_model();
    let cfg = DaemonConfig {
        queue_capacity: 32,
        deadline_ms: 100_000, // manual clock never advances: no deadline noise
        max_batch: 4,
        degrade_beams: vec![16, 4],
        overload_trip: 2,
        worker_timeout_ms: 100_000, // must cover the deadline (cfg.validate)
    };
    // the clock never advances: batching is driven purely by drain()
    let mut d = Daemon::new(
        arc_model(),
        ServeConfig::default(),
        cfg,
        1,
        None,
        Box::new(ManualClock::new()),
    )
    .unwrap();

    // fill the queue to capacity, then drain: flushes of 4 leave the queue
    // above the highwater (16) long enough to trip each tier in turn.
    // Expected tier per flush: 0,0 (streak trips after flush 2), 1,1
    // (trips again), 2,2,2,2 (last flush empties the queue -> back to 1).
    let n = 32usize;
    for i in 0..n {
        let (id, immediate) = d.submit_features(test.x(i));
        assert_eq!(id, i as u64);
        assert!(immediate.is_none(), "request {i} admitted");
    }
    let out = d.drain();
    assert_eq!(out.len(), n, "every admitted request answered");

    let full = oracle_at_beam(ServeConfig::default().beam);
    let deg16 = oracle_at_beam(16);
    let deg4 = oracle_at_beam(4);
    for r in &out {
        let i = r.id as usize;
        let want_beam = match i {
            0..=7 => None,
            8..=15 => Some(16usize),
            _ => Some(4usize),
        };
        match (&r.kind, want_beam) {
            (ResponseKind::Ok(topk), None) => {
                assert_topk_bit_eq(topk, &full.predict_one(test.x(i)), &format!("request {i}"));
            }
            (ResponseKind::Degraded { beam, topk }, Some(want)) => {
                assert_eq!(*beam, want, "request {i} tier");
                let oracle = if want == 16 { &deg16 } else { &deg4 };
                assert_topk_bit_eq(
                    topk,
                    &oracle.predict_one(test.x(i)),
                    &format!("request {i} (degraded beam={want})"),
                );
            }
            (kind, want) => panic!("request {i}: got {kind:?}, expected beam {want:?}"),
        }
    }
    let stats = d.stats();
    assert_eq!(stats.ok, 8);
    assert_eq!(stats.degraded, 24);
    assert_eq!(stats.tier_changes, 3, "two step-downs plus one restore");
    assert_eq!(d.tier(), 1, "last flush emptied the queue: one tier back up");

    // the queue stays drained: each further flush-to-empty restores one
    // tier, and service at tier 0 is full-beam `ok` again
    let (_, none) = d.submit_features(test.x(0));
    assert!(none.is_none());
    let out = d.drain();
    assert!(
        matches!(&out[0].kind, ResponseKind::Degraded { beam: 16, .. }),
        "still one tier down: {:?}",
        out[0].kind
    );
    assert_eq!(d.tier(), 0, "restored to the full beam");
    let (id, none) = d.submit_features(test.x(1));
    assert!(none.is_none());
    let out = d.drain();
    assert_eq!(out[0].id, id);
    match &out[0].kind {
        ResponseKind::Ok(topk) => {
            assert_topk_bit_eq(topk, &full.predict_one(test.x(1)), "after restore")
        }
        other => panic!("expected full-beam ok after restore, got {other:?}"),
    }
    assert!(d.stats().accounted(d.queue_len()));
}

/// The chaos plan: `REPRO_FAULTS` when set (the CI chaos job), else a
/// fixed seeded mix of all three fault kinds. An unparsable override is a
/// hard failure — the chaos leg must never quietly run clean.
fn chaos_plan() -> FaultPlan {
    FaultPlan::from_env()
        .expect("REPRO_FAULTS must parse")
        .unwrap_or_else(|| {
            FaultPlan::parse("seed=1337,panic=0.12,slow=0.2:3,malform=0.15").unwrap()
        })
}

const CHAOS_N: usize = 120;

/// One deterministic chaos run: a fixed submission schedule over a manual
/// clock, returning the daemon and the full `(id, response)` trace.
fn chaos_run(plan: &FaultPlan) -> (Daemon, Vec<(u64, ResponseKind)>) {
    let (_, test) = centroid_model();
    let cfg = DaemonConfig {
        queue_capacity: 10,
        deadline_ms: 40,
        max_batch: 8,
        degrade_beams: vec![16, 4],
        overload_trip: 2,
        worker_timeout_ms: 2000, // declared slow stages must never wedge
    };
    let clock = ManualClock::new();
    let mut d = Daemon::new(
        arc_model(),
        ServeConfig::default(),
        cfg,
        2,
        Some(plan.clone()),
        Box::new(clock.clone()),
    )
    .unwrap();
    let mut trace = Vec::new();
    for i in 0..CHAOS_N {
        clock.advance((i % 3) as u64);
        let (id, immediate) = d.submit_line(&query_line(test, i));
        assert_eq!(id, i as u64, "ids are the submission order");
        if let Some(kind) = immediate {
            trace.push((id, kind));
        }
        if i % 6 == 5 {
            for r in d.pump(false) {
                trace.push((r.id, r.kind));
            }
        }
        if i % 17 == 16 {
            clock.advance(11); // blow past the coalescing window
            for r in d.pump(true) {
                trace.push((r.id, r.kind));
            }
        }
    }
    for r in d.drain() {
        trace.push((r.id, r.kind));
    }
    (d, trace)
}

/// The headline chaos test: exactly one typed response per submitted
/// request, every successful response bit-exact at its tagged beam width,
/// an identical trace on a second run, and bit-identical fault-free
/// service after the plan is cleared.
#[test]
fn chaos_never_drops_requests_and_recovers_bit_identically() {
    let plan = chaos_plan();
    let (_, test) = centroid_model();
    let (mut d, trace) = chaos_run(&plan);

    // exactly one response per submitted request
    assert_eq!(trace.len(), CHAOS_N, "one response per request");
    let mut ids: Vec<u64> = trace.iter().map(|(id, _)| *id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..CHAOS_N as u64).collect::<Vec<_>>(), "each request exactly once");
    let stats = d.stats();
    assert_eq!(stats.submitted, CHAOS_N as u64);
    assert!(stats.accounted(0), "accounting holds after drain: {stats:?}");
    assert_eq!(
        stats.respawns,
        stats.worker_panics + stats.worker_timeouts,
        "every crash respawns the worker exactly once"
    );

    // every response is typed and, when served, bit-exact for its beam
    let full = oracle_at_beam(ServeConfig::default().beam);
    let deg16 = oracle_at_beam(16);
    let deg4 = oracle_at_beam(4);
    for (id, kind) in &trace {
        let i = *id as usize;
        match kind {
            ResponseKind::Ok(topk) => {
                assert_topk_bit_eq(topk, &full.predict_one(test.x(i)), &format!("request {i}"));
            }
            ResponseKind::Degraded { beam, topk } => {
                let oracle = match beam {
                    16 => &deg16,
                    4 => &deg4,
                    other => panic!("request {i}: beam {other} not on the ladder"),
                };
                assert_topk_bit_eq(
                    topk,
                    &oracle.predict_one(test.x(i)),
                    &format!("request {i} (degraded beam={beam})"),
                );
            }
            ResponseKind::Rejected(_) => {} // typed shed or deadline cancel
            ResponseKind::Error(msg) => {
                assert!(
                    msg.contains("malformed request")
                        || msg.contains("panicked")
                        || msg.contains("timed out"),
                    "request {i}: untyped error {msg:?}"
                );
            }
        }
    }

    // chaos is reproducible: the same plan over the same schedule yields
    // the identical trace, fault for fault, bit for bit
    let (_, trace2) = chaos_run(&plan);
    assert_eq!(trace, trace2, "chaos trace must reproduce exactly");

    // recovery: clear the faults, let the tier restore, and service is
    // bit-identical to a run where no fault ever fired
    d.set_faults(None);
    while d.tier() > 0 {
        let (_, none) = d.submit_features(test.x(0));
        assert!(none.is_none());
        d.drain();
    }
    // 8 queries fit the chaos queue (capacity 10) without shedding
    for i in 0..8 {
        let (_, none) = d.submit_features(test.x(i));
        assert!(none.is_none(), "post-recovery request {i} admitted");
    }
    let out = d.drain();
    assert_eq!(out.len(), 8);
    for r in &out {
        match &r.kind {
            ResponseKind::Ok(topk) => {
                let i = (r.id - out[0].id) as usize;
                assert_topk_bit_eq(
                    topk,
                    &full.predict_one(test.x(i)),
                    &format!("post-recovery request {i}"),
                );
            }
            other => panic!("post-recovery response not ok: {other:?}"),
        }
    }
    assert!(d.stats().accounted(0));
}

/// Socket transport smoke test: a client connects, sends a query, a
/// malformed line and `shutdown`, and gets exactly one typed response per
/// line back on its own connection.
#[cfg(unix)]
#[test]
fn socket_round_trip_answers_every_line() {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::time::Duration;

    let (_, test) = centroid_model();
    let path = std::env::temp_dir().join(format!(
        "adv_softmax_daemon_chaos_{}.sock",
        std::process::id()
    ));
    let mut d = Daemon::new(
        arc_model(),
        ServeConfig::default(),
        DaemonConfig { deadline_ms: 1000, ..Default::default() },
        1,
        None,
        Box::new(RealClock::new()),
    )
    .unwrap();
    let server = {
        let path = path.clone();
        std::thread::spawn(move || daemon::run_socket_daemon(&mut d, &path).unwrap())
    };
    // the daemon binds shortly after spawn; poll instead of racing it
    let mut stream = loop {
        match UnixStream::connect(&path) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{}", query_line(test, 0)).unwrap();
    writeln!(stream, "definitely not floats").unwrap();
    writeln!(stream, "shutdown").unwrap();
    stream.flush().unwrap();
    let mut lines = Vec::new();
    for _ in 0..2 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "daemon closed early");
        lines.push(line.trim().to_string());
    }
    let stats = server.join().unwrap();
    assert_eq!(stats.ok, 1);
    assert_eq!(stats.malformed, 1);
    assert!(stats.accounted(0));
    // responses carry the per-client request index; arrival order may
    // differ (the malformed error is answered at admission)
    lines.sort();
    assert!(lines[0].starts_with("0 ok "), "query response: {:?}", lines[0]);
    assert!(
        lines[1].starts_with("1 error") && lines[1].contains("malformed request"),
        "malformed response: {:?}",
        lines[1]
    );
    assert!(!path.exists(), "socket file removed on shutdown");
}
