//! Runtime audit of the [`SharedMut`] disjointness contract
//! (`--features shared_mut_audit`).
//!
//! Two directions:
//!
//! * **Soundness of the shard maps** — randomized disjoint plans (modulo
//!   sharding, contiguous spans, random partitions) must never trip the
//!   audit, using the same seeded-generator pattern as
//!   `proptest_invariants.rs`.
//! * **Sensitivity of the audit** — a deliberately overlapping plan must
//!   panic, and the diagnostic must name both claiming jobs and both
//!   ranges so the report is actionable without a debugger.
//!
//! The rest of the suite doubles as the real-workload audit: CI runs
//! `cargo test --features shared_mut_audit`, which drives every sharded
//! path (train, tree fit, PCA, eval, serve) with claims recorded.

#![cfg(feature = "shared_mut_audit")]

use adv_softmax::utils::{Pool, Rng, SharedMut};
use std::sync::Barrier;

/// Run `prop` over `cases` random seeds; panic with the seed on failure.
fn for_all_seeds(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xd15_701A7 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(">>> property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Property: `i % workers == shard` plans (the codebase's scatter pattern)
/// never trip the audit, for random sizes and worker counts.
#[test]
fn prop_modulo_shard_plans_never_trip() {
    for_all_seeds(24, |rng| {
        let workers = 2 + rng.below(4);
        let n = 64 + rng.below(1000);
        let pool = Pool::new(workers);
        let mut buf = vec![0usize; n];
        {
            let view = SharedMut::new(&mut buf);
            let view_ref = &view;
            pool.run_sharded(move |shard| {
                for i in 0..n {
                    if i % workers == shard {
                        // SAFETY: index i is written only by shard i % workers.
                        unsafe { *view_ref.get_mut(i) = i + 1 };
                    }
                }
            });
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i + 1));
    });
}

/// Property: contiguous-span plans ([`Pool::for_each_span`], which claims
/// through `slice_mut` internally) never trip the audit.
#[test]
fn prop_span_plans_never_trip() {
    for_all_seeds(24, |rng| {
        let workers = 1 + rng.below(5);
        let n_items = 1 + rng.below(200);
        let item_len = 1 + rng.below(8);
        let pool = Pool::new(workers);
        let mut buf = vec![0u32; n_items * item_len];
        pool.for_each_span(&mut buf, item_len, |first, span| {
            for (j, v) in span.iter_mut().enumerate() {
                *v = (first * item_len + j) as u32;
            }
        });
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32));
    });
}

/// Property: random contiguous partitions with round-robin chunk
/// assignment (mixing `slice_mut` spans of random width) never trip.
#[test]
fn prop_random_partition_plans_never_trip() {
    for_all_seeds(24, |rng| {
        let workers = 2 + rng.below(4);
        let n = 50 + rng.below(500);
        let mut cuts = vec![0usize, n];
        for _ in 0..6 {
            cuts.push(rng.below(n + 1));
        }
        cuts.sort_unstable();
        cuts.dedup();
        let chunks: Vec<(usize, usize)> = cuts.windows(2).map(|w| (w[0], w[1])).collect();
        let pool = Pool::new(workers);
        let mut buf = vec![0u8; n];
        {
            let view = SharedMut::new(&mut buf);
            let view_ref = &view;
            let chunks_ref = &chunks;
            pool.run_sharded(move |shard| {
                for (t, &(lo, hi)) in chunks_ref.iter().enumerate() {
                    if t % workers == shard && hi > lo {
                        // SAFETY: chunk t has exactly one writer (shard t % workers).
                        let span = unsafe { view_ref.slice_mut(lo, hi - lo) };
                        span.iter_mut().for_each(|v| *v = 1);
                    }
                }
            });
        }
        assert!(buf.iter().all(|&v| v == 1), "every index written exactly once");
    });
}

/// A deliberately overlapping plan must panic, and the diagnostic must
/// name both jobs (thread names) and both ranges. The overlap is made
/// deterministic with a barrier: the worker (`pool-1`) claims `[0, 8)`
/// first, then the calling thread claims `[4, 12)` and is vetoed — on the
/// caller's own thread, so the original panic message propagates through
/// `run_sharded` unwrapped.
#[test]
fn overlapping_claims_panic_naming_both_jobs_and_ranges() {
    let pool = Pool::new(2);
    let barrier = Barrier::new(2);
    let mut buf = vec![0u32; 16];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let view = SharedMut::new(&mut buf);
        let (view_ref, barrier_ref) = (&view, &barrier);
        pool.run_sharded(move |shard| {
            if shard == 1 {
                // SAFETY: deliberate-overlap fixture; the audit vetoes the
                // *second* claim before any aliased write can happen.
                let span = unsafe { view_ref.slice_mut(0, 8) };
                span[0] = 1;
                barrier_ref.wait();
            } else {
                barrier_ref.wait(); // shard 1's claim lands first
                // SAFETY: deliberate-overlap fixture (see above).
                let _ = unsafe { view_ref.slice_mut(4, 8) }; // [4, 12)
            }
        });
    }));
    let err = result.expect_err("overlapping cross-thread claims must panic");
    let msg = err
        .downcast_ref::<String>()
        .map(String::as_str)
        .or_else(|| err.downcast_ref::<&str>().copied())
        .unwrap_or("");
    assert!(msg.contains("SharedMut audit"), "audit diagnostic, got: {msg:?}");
    assert!(msg.contains("[4, 12)"), "offending range named: {msg:?}");
    assert!(msg.contains("[0, 8)"), "earlier range named: {msg:?}");
    assert!(msg.contains("pool-1"), "earlier claimant named: {msg:?}");
}

/// Same story through `get_mut`: two threads claiming one index panic.
#[test]
fn cross_thread_same_index_panics() {
    let pool = Pool::new(2);
    let barrier = Barrier::new(2);
    let mut buf = vec![0u32; 4];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let view = SharedMut::new(&mut buf);
        let (view_ref, barrier_ref) = (&view, &barrier);
        pool.run_sharded(move |shard| {
            if shard == 1 {
                // SAFETY: deliberate-overlap fixture; audit vetoes the
                // second claim.
                unsafe { *view_ref.get_mut(2) = 7 };
                barrier_ref.wait();
            } else {
                barrier_ref.wait();
                // SAFETY: deliberate-overlap fixture (see above).
                unsafe { *view_ref.get_mut(2) = 9 };
            }
        });
    }));
    let err = result.expect_err("same-index cross-thread claims must panic");
    let msg = err
        .downcast_ref::<String>()
        .map(String::as_str)
        .or_else(|| err.downcast_ref::<&str>().copied())
        .unwrap_or("");
    assert!(msg.contains("[2, 3)"), "single-index range named: {msg:?}");
}

/// Overlapping claims from *one* thread are sequential borrows, not data
/// races: the audit must stay silent.
#[test]
fn same_thread_overlapping_claims_are_fine() {
    let mut buf = vec![0u32; 8];
    {
        let view = SharedMut::new(&mut buf);
        for _ in 0..3 {
            // SAFETY: single-threaded; the borrows are sequential.
            let span = unsafe { view.slice_mut(0, 8) };
            span[0] += 1;
        }
        // SAFETY: single-threaded; the borrows are sequential.
        unsafe { *view.get_mut(0) += 1 };
    }
    assert_eq!(buf[0], 4);
}

/// Under the audit feature, bounds checks are hard asserts even in
/// release builds: an out-of-range claim panics before any pointer math.
#[test]
fn audit_mode_has_hard_bounds_checks() {
    let mut buf = vec![0u32; 4];
    let view = SharedMut::new(&mut buf);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // SAFETY: out of bounds on purpose; the audit's hard assert fires
        // before the pointer is formed.
        let _ = unsafe { view.get_mut(4) };
    }));
    assert!(r.is_err(), "out-of-bounds get_mut must panic under the audit");
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // SAFETY: out of bounds on purpose (see above).
        let _ = unsafe { view.slice_mut(2, 3) };
    }));
    assert!(r.is_err(), "out-of-bounds slice_mut must panic under the audit");
}
