//! Serving-path parity: beam-search + exact re-rank vs the O(C) oracle,
//! bit-determinism across worker counts and submission patterns, and
//! checkpoint roundtrips. Pure host path — no PJRT artifacts needed.

use adv_softmax::config::{DatasetPreset, QuantMode, ServeConfig, SyntheticConfig, TreeConfig};
use adv_softmax::data::{Dataset, Splits};
use adv_softmax::sampler::AdversarialSampler;
use adv_softmax::serve::{evaluate_serving, Predictor, RequestBatcher, ServingModel, TopK};
use adv_softmax::utils::Pool;
use std::sync::OnceLock;

/// Shared fixture: the aux-tree fit is the expensive part, so build the
/// model once for the whole test binary.
fn centroid_model() -> &'static (ServingModel, Dataset) {
    static MODEL: OnceLock<(ServingModel, Dataset)> = OnceLock::new();
    MODEL.get_or_init(build_centroid_model)
}

/// A trained-shaped model without PJRT: centroid classifier rows (w_y =
/// scaled mean of class-y training features — the convex objective's
/// rough direction) plus the genuinely fitted auxiliary tree, with the
/// Eq. 5 correction on, over the tiny preset (C = 256, K = 64).
fn build_centroid_model() -> (ServingModel, Dataset) {
    let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
    cfg.n_train = 4096;
    cfg.n_test = 512;
    let splits = Splits::synthetic(&cfg);
    let train = &splits.train;
    let (c, k) = (train.num_classes, train.feat_dim);
    let mut w = vec![0f32; c * k];
    let mut counts = vec![0f32; c];
    for i in 0..train.len() {
        let y = train.y(i) as usize;
        counts[y] += 1.0;
        for (wv, xv) in w[y * k..(y + 1) * k].iter_mut().zip(train.x(i).iter()) {
            *wv += *xv;
        }
    }
    for y in 0..c {
        if counts[y] > 0.0 {
            let scale = 4.0 / counts[y];
            for wv in w[y * k..(y + 1) * k].iter_mut() {
                *wv *= scale;
            }
        }
    }
    let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
    let (aux, _) = AdversarialSampler::fit(train, &tcfg, 5);
    let model = ServingModel {
        num_classes: c,
        feat_dim: k,
        w,
        b: vec![0f32; c],
        aux: Some(aux),
        correct_bias: true,
    };
    (model, splits.test)
}

fn assert_preds_bit_eq(a: &[TopK], b: &[TopK], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (pa, pb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(pa.labels, pb.labels, "{ctx}: labels of query {i}");
        let sa: Vec<u32> = pa.scores.iter().map(|s| s.to_bits()).collect();
        let sb: Vec<u32> = pb.scores.iter().map(|s| s.to_bits()).collect();
        assert_eq!(sa, sb, "{ctx}: score bits of query {i}");
    }
}

/// Acceptance bar: at the default beam width, beam + exact re-rank
/// recovers ≥ 95% of the exact O(C) oracle's top-k on held-out data.
#[test]
fn beam_rerank_recall_vs_exact_oracle() {
    let (model, test) = centroid_model();
    let exact = Predictor::new(model, ServeConfig { exact: true, ..Default::default() })
        .unwrap();
    let beam = Predictor::new(model, ServeConfig::default()).unwrap();
    let pool = Pool::serial();
    let n = test.len().min(256);
    let xs = &test.features[..n * test.feat_dim];
    let po = exact.predict_batch_with(xs, n, &pool);
    let pb = beam.predict_batch_with(xs, n, &pool);
    let kk = exact.k();
    let (mut hit, mut tot) = (0usize, 0usize);
    for (o, b) in po.iter().zip(pb.iter()) {
        assert_eq!(o.labels.len(), kk, "oracle returns a full top-{kk}");
        for y in o.labels.iter() {
            tot += 1;
            if b.labels.contains(y) {
                hit += 1;
            }
        }
    }
    let recall = hit as f64 / tot as f64;
    assert!(
        recall >= 0.95,
        "recall@{kk} of beam (B={}) vs exact oracle: {recall:.4} < 0.95",
        ServeConfig::default().beam
    );
}

/// Acceptance bar: predictions are bit-identical across
/// `parallelism ∈ {1, 2, 7}` and for batched vs one-at-a-time submission,
/// on both the beam and the exact path.
#[test]
fn predictions_bit_identical_across_parallelism_and_batching() {
    let (model, test) = centroid_model();
    let kf = test.feat_dim;
    let n = 131; // ragged vs every lane/span boundary
    let xs = &test.features[..n * kf];
    for exact in [false, true] {
        let cfg = ServeConfig { exact, ..Default::default() };
        let pred = Predictor::new(model, cfg).unwrap();
        let ctx = if exact { "exact" } else { "beam" };
        let base = pred.predict_batch_with(xs, n, &Pool::new(1));
        for workers in [2usize, 7] {
            let par = pred.predict_batch_with(xs, n, &Pool::new(workers));
            assert_preds_bit_eq(&base, &par, &format!("{ctx}, workers={workers}"));
        }
        // one-at-a-time submission matches the batch row for row
        for i in (0..n).step_by(13) {
            let one = pred.predict_one(&xs[i * kf..(i + 1) * kf]);
            assert_preds_bit_eq(
                std::slice::from_ref(&base[i]),
                std::slice::from_ref(&one),
                &format!("{ctx}, single query {i}"),
            );
        }
    }
}

/// The request batcher's coalesced flush equals the direct batch, in
/// submission order, at several pool widths.
#[test]
fn request_batcher_matches_direct_batch() {
    let (model, test) = centroid_model();
    let kf = test.feat_dim;
    let n = 67;
    let xs = &test.features[..n * kf];
    let pred = Predictor::new(model, ServeConfig::default()).unwrap();
    let direct = pred.predict_batch_with(xs, n, &Pool::serial());
    for workers in [1usize, 3] {
        let pool = Pool::new(workers);
        let mut batcher = RequestBatcher::new(&pred);
        for i in 0..n {
            assert_eq!(batcher.submit(&xs[i * kf..(i + 1) * kf]), i);
        }
        let flushed = batcher.flush_with(&pool);
        assert_preds_bit_eq(&direct, &flushed, &format!("batcher, workers={workers}"));
    }
}

/// With the beam wide enough to cover every leaf, the candidate set is the
/// whole label space and the re-ranked top-k must equal the exact oracle
/// bit for bit — the score-parity contract between
/// `Scorer::score_candidates_with` and the dense sweep, end to end.
#[test]
fn full_beam_equals_exact_oracle_bitwise() {
    let (shared, test) = centroid_model();
    let kf = test.feat_dim;
    let n = 64;
    let xs = &test.features[..n * kf];
    for correct_bias in [true, false] {
        let mut model = shared.clone();
        model.correct_bias = correct_bias;
        let exact = Predictor::new(&model, ServeConfig { exact: true, ..Default::default() })
            .unwrap();
        let full = Predictor::new(
            &model,
            ServeConfig { beam: model.num_classes, ..Default::default() },
        )
        .unwrap();
        let po = exact.predict_batch_with(xs, n, &Pool::serial());
        let pf = full.predict_batch_with(xs, n, &Pool::serial());
        assert_preds_bit_eq(&po, &pf, &format!("correct_bias={correct_bias}"));
    }
}

/// Checkpoint roundtrip: a saved-and-reloaded model predicts bit-
/// identically, on both paths.
#[test]
fn serving_model_checkpoint_roundtrip() {
    let (model, test) = centroid_model();
    let path = std::env::temp_dir().join("adv_softmax_test_serving_model.json");
    model.save(&path).unwrap();
    let back = ServingModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.num_classes, model.num_classes);
    assert_eq!(back.feat_dim, model.feat_dim);
    assert_eq!(back.correct_bias, model.correct_bias);
    let kf = test.feat_dim;
    let n = 32;
    let xs = &test.features[..n * kf];
    for exact in [false, true] {
        let cfg = ServeConfig { exact, ..Default::default() };
        let a = Predictor::new(model, cfg).unwrap().predict_batch_with(
            xs,
            n,
            &Pool::serial(),
        );
        let b = Predictor::new(&back, cfg)
            .unwrap()
            .predict_batch_with(xs, n, &Pool::serial());
        assert_preds_bit_eq(&a, &b, if exact { "exact" } else { "beam" });
    }
}

/// Acceptance bar for quantized serving: on the full held-out split and
/// the production beam path, f16 rows cost at most 0.005 of recall@k
/// (and P@1) vs the f32 reference; i8 + per-row scale stays within a
/// looser 0.03.
#[test]
fn quantized_recall_stays_within_bound_of_f32() {
    let (model, test) = centroid_model();
    let pool = Pool::serial();
    let base = Predictor::new(
        model,
        ServeConfig { quantize: QuantMode::Off, ..Default::default() },
    )
    .unwrap();
    let mf = evaluate_serving(&base, test, &pool);
    for (mode, bound) in [(QuantMode::F16, 0.005), (QuantMode::I8, 0.03)] {
        let pred =
            Predictor::new(model, ServeConfig { quantize: mode, ..Default::default() }).unwrap();
        let mq = evaluate_serving(&pred, test, &pool);
        assert_eq!(mq.n, mf.n);
        assert!(
            (mf.recall_at_k - mq.recall_at_k).abs() <= bound,
            "{mode}: recall@{} {:.4} drifted more than {bound} from f32 {:.4}",
            mq.k,
            mq.recall_at_k,
            mf.recall_at_k
        );
        assert!(
            (mf.p_at_1 - mq.p_at_1).abs() <= bound,
            "{mode}: P@1 {:.4} drifted more than {bound} from f32 {:.4}",
            mq.p_at_1,
            mf.p_at_1
        );
    }
}

/// Quantized predictions are bit-identical across worker counts and for
/// batcher-coalesced vs direct submission — quantization changes *which*
/// scores are computed, never their determinism.
#[test]
fn quantized_predictions_bit_identical_across_worker_counts() {
    let (model, test) = centroid_model();
    let kf = test.feat_dim;
    let n = 131; // ragged vs every lane/span boundary
    let xs = &test.features[..n * kf];
    for mode in [QuantMode::F16, QuantMode::I8] {
        let pred =
            Predictor::new(model, ServeConfig { quantize: mode, ..Default::default() }).unwrap();
        let base = pred.predict_batch_with(xs, n, &Pool::new(1));
        for workers in [2usize, 7] {
            let par = pred.predict_batch_with(xs, n, &Pool::new(workers));
            assert_preds_bit_eq(&base, &par, &format!("{mode}, workers={workers}"));
        }
        let mut batcher = RequestBatcher::new(&pred);
        for i in 0..n {
            batcher.submit(&xs[i * kf..(i + 1) * kf]);
        }
        let flushed = batcher.flush_with(&Pool::new(3));
        assert_preds_bit_eq(&base, &flushed, &format!("{mode}, batcher"));
    }
}

/// The quantize-then-score contract end to end: with the beam covering
/// every leaf, the quantized re-rank must equal the quantized exact sweep
/// bit for bit — candidate scoring and the dense sweep decode rows through
/// the same kernels.
#[test]
fn full_beam_equals_exact_oracle_bitwise_quantized() {
    let (model, test) = centroid_model();
    let kf = test.feat_dim;
    let n = 64;
    let xs = &test.features[..n * kf];
    for mode in [QuantMode::F16, QuantMode::I8] {
        let exact = Predictor::new(
            model,
            ServeConfig { exact: true, quantize: mode, ..Default::default() },
        )
        .unwrap();
        let full = Predictor::new(
            model,
            ServeConfig { beam: model.num_classes, quantize: mode, ..Default::default() },
        )
        .unwrap();
        let po = exact.predict_batch_with(xs, n, &Pool::serial());
        let pf = full.predict_batch_with(xs, n, &Pool::serial());
        assert_preds_bit_eq(&po, &pf, &format!("quantize={mode}"));
    }
}

/// The serving eval workload (`repro serve --eval`) reports sane metrics:
/// the centroid model beats chance by a wide margin, recall@k dominates
/// P@1, and the beam path lands close to the oracle.
#[test]
fn serving_eval_metrics_sane_and_beam_close_to_exact() {
    let (model, test) = centroid_model();
    let exact = Predictor::new(model, ServeConfig { exact: true, ..Default::default() })
        .unwrap();
    let beam = Predictor::new(model, ServeConfig::default()).unwrap();
    let pool = Pool::new(3);
    let me = evaluate_serving(&exact, test, &pool);
    let mb = evaluate_serving(&beam, test, &pool);
    assert_eq!(me.n, test.len());
    for m in [&me, &mb] {
        assert!(m.p_at_1 > 0.1, "well above 1/C = {:.4}: {:.4}", 1.0 / 256.0, m.p_at_1);
        assert!(m.recall_at_k >= m.p_at_1);
        assert!(m.recall_at_k <= 1.0);
    }
    assert!(
        (me.p_at_1 - mb.p_at_1).abs() <= 0.05,
        "beam P@1 {:.4} vs exact {:.4}",
        mb.p_at_1,
        me.p_at_1
    );
    assert!(
        (me.recall_at_k - mb.recall_at_k).abs() <= 0.05,
        "beam recall {:.4} vs exact {:.4}",
        mb.recall_at_k,
        me.recall_at_k
    );
}
