//! Integration: AOT artifacts → PJRT runtime → numerics cross-checked
//! against pure-rust implementations of the same math.
//!
//! Requires `make artifacts` (fails with a clear message otherwise).

use adv_softmax::linalg::{dot, log_sigmoid, sigmoid};
use adv_softmax::runtime::{lit_f32, lit_i32, read_f32, read_i32, Registry};
use adv_softmax::utils::Rng;

fn registry() -> Registry {
    Registry::open_default().expect("artifacts missing — run `make artifacts` first")
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn manifest_lists_all_entry_points() {
    let reg = registry();
    for prefix in [
        "ns_grad_", "nce_grad_", "ove_grad_", "softmax_grad_",
        "eval_chunk_B", "eval_chunk_plain_", "scores_",
    ] {
        reg.get_by_prefix(prefix).unwrap_or_else(|e| panic!("{prefix}: {e}"));
    }
    assert!(reg.get("nonexistent").is_err());
    assert!(reg.get_by_prefix("zzz").is_err());
}

#[test]
fn ns_grad_matches_rust_reference() {
    let reg = registry();
    let exec = reg.get_by_prefix("ns_grad_").unwrap();
    let b = reg.manifest.shapes.train_b;
    let k = reg.manifest.shapes.feat_k;
    let mut rng = Rng::new(1);
    let x = randv(&mut rng, b * k);
    let wp = randv(&mut rng, b * k);
    let bp = randv(&mut rng, b);
    let wn = randv(&mut rng, b * k);
    let bn = randv(&mut rng, b);
    let lpn_p: Vec<f32> = randv(&mut rng, b).iter().map(|v| v - 3.0).collect();
    let lpn_n: Vec<f32> = randv(&mut rng, b).iter().map(|v| v - 3.0).collect();
    let lam = 0.01f32;

    let outs = exec
        .run(&[
            lit_f32(&x, &[b, k]).unwrap(),
            lit_f32(&wp, &[b, k]).unwrap(),
            lit_f32(&bp, &[b]).unwrap(),
            lit_f32(&wn, &[b, k]).unwrap(),
            lit_f32(&bn, &[b]).unwrap(),
            lit_f32(&lpn_p, &[b]).unwrap(),
            lit_f32(&lpn_n, &[b]).unwrap(),
            lit_f32(&[lam], &[1]).unwrap(),
        ])
        .unwrap();
    let loss = read_f32(&outs[0]).unwrap();
    let gwp = read_f32(&outs[1]).unwrap();
    let gbp = read_f32(&outs[2]).unwrap();

    // rust reference (paper Eq. 6)
    for i in 0..b {
        let xi_p = dot(&x[i * k..(i + 1) * k], &wp[i * k..(i + 1) * k]) + bp[i];
        let xi_n = dot(&x[i * k..(i + 1) * k], &wn[i * k..(i + 1) * k]) + bn[i];
        let expect = -log_sigmoid(xi_p) - log_sigmoid(-xi_n)
            + lam * (xi_p + lpn_p[i]).powi(2)
            + lam * (xi_n + lpn_n[i]).powi(2);
        assert!(
            (loss[i] - expect).abs() < 2e-4 * (1.0 + expect.abs()),
            "loss[{i}]: {} vs {expect}",
            loss[i]
        );
        let dxi_p = -sigmoid(-xi_p) + 2.0 * lam * (xi_p + lpn_p[i]);
        assert!((gbp[i] - dxi_p).abs() < 2e-4, "gbp[{i}]");
        for j in (0..k).step_by(17) {
            let expect_g = dxi_p * x[i * k + j];
            assert!(
                (gwp[i * k + j] - expect_g).abs() < 2e-4 * (1.0 + expect_g.abs()),
                "gwp[{i},{j}]"
            );
        }
    }
}

#[test]
fn eval_chunk_streaming_reduction_is_correct() {
    let reg = registry();
    let exec = reg.get_by_prefix("eval_chunk_plain_").unwrap();
    let b = reg.manifest.shapes.eval_b;
    let cc = reg.manifest.shapes.eval_c;
    let k = reg.manifest.shapes.feat_k;
    let mut rng = Rng::new(2);
    let x = randv(&mut rng, b * k);
    let wc = randv(&mut rng, cc * k);
    let bc = randv(&mut rng, cc);
    let y_rel: Vec<i32> = (0..b)
        .map(|i| if i % 3 == 0 { -1 } else { (i % cc) as i32 })
        .collect();

    let outs = exec
        .run(&[
            lit_f32(&x, &[b, k]).unwrap(),
            lit_f32(&wc, &[cc, k]).unwrap(),
            lit_f32(&bc, &[cc]).unwrap(),
            lit_i32(&y_rel, &[b]).unwrap(),
        ])
        .unwrap();
    let cmax = read_f32(&outs[0]).unwrap();
    let cargmax = read_i32(&outs[1]).unwrap();
    let csum = read_f32(&outs[2]).unwrap();
    let ctrue = read_f32(&outs[3]).unwrap();

    for i in (0..b).step_by(37) {
        let scores: Vec<f32> = (0..cc)
            .map(|c| dot(&x[i * k..(i + 1) * k], &wc[c * k..(c + 1) * k]) + bc[c])
            .collect();
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let am = (0..cc).max_by(|&a, &b2| scores[a].total_cmp(&scores[b2])).unwrap();
        let se: f32 = scores.iter().map(|s| (s - m).exp()).sum();
        assert!((cmax[i] - m).abs() < 1e-3, "max[{i}]");
        assert_eq!(cargmax[i] as usize, am, "argmax[{i}]");
        assert!((csum[i] - se).abs() < 1e-2 * se, "sumexp[{i}]");
        if y_rel[i] >= 0 {
            assert!((ctrue[i] - scores[y_rel[i] as usize]).abs() < 1e-3);
        } else {
            assert!(ctrue[i] < -1.0e29, "sentinel expected");
        }
    }
}

#[test]
fn scores_artifact_is_plain_matmul() {
    let reg = registry();
    let exec = reg.get_by_prefix("scores_").unwrap();
    let (b, ka) = (exec.meta.inputs[0].shape[0], exec.meta.inputs[0].shape[1]);
    let ca = exec.meta.inputs[1].shape[0];
    let mut rng = Rng::new(3);
    let x = randv(&mut rng, b * ka);
    let wc = randv(&mut rng, ca * ka);
    let bc = randv(&mut rng, ca);
    let outs = exec
        .run(&[
            lit_f32(&x, &[b, ka]).unwrap(),
            lit_f32(&wc, &[ca, ka]).unwrap(),
            lit_f32(&bc, &[ca]).unwrap(),
        ])
        .unwrap();
    let s = read_f32(&outs[0]).unwrap();
    for (i, c) in [(0, 0), (b / 2, ca / 2), (b - 1, ca - 1)] {
        let expect = dot(&x[i * ka..(i + 1) * ka], &wc[c * ka..(c + 1) * ka]) + bc[c];
        assert!(
            (s[i * ca + c] - expect).abs() < 1e-3 * (1.0 + expect.abs()),
            "s[{i},{c}]"
        );
    }
}

#[test]
fn wrong_arity_is_rejected() {
    let reg = registry();
    let exec = reg.get_by_prefix("scores_").unwrap();
    assert!(exec.run(&[]).is_err());
}

// NB: the xla crate's PjRtLoadedExecutable is Rc-based (!Send), so all
// PJRT execution stays on the coordinator thread by design; the training
// pipeline overlaps *batch generation* (pure rust) with execution instead.

#[test]
fn repeated_execution_is_deterministic() {
    let reg = registry();
    let exec = reg.get_by_prefix("scores_").unwrap();
    let (b, ka) = (exec.meta.inputs[0].shape[0], exec.meta.inputs[0].shape[1]);
    let ca = exec.meta.inputs[1].shape[0];
    let mut rng = Rng::new(4);
    let x = randv(&mut rng, b * ka);
    let wc = randv(&mut rng, ca * ka);
    let bc = randv(&mut rng, ca);
    let run = || {
        let outs = exec
            .run(&[
                lit_f32(&x, &[b, ka]).unwrap(),
                lit_f32(&wc, &[ca, ka]).unwrap(),
                lit_f32(&bc, &[ca]).unwrap(),
            ])
            .unwrap();
        read_f32(&outs[0]).unwrap()
    };
    assert_eq!(run(), run());
}
