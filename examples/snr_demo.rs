//! Theorem 2 demo: the gradient signal-to-noise ratio η̄ is maximal when
//! negative samples come from the data distribution itself.
//!
//! Reproduces the theory section's claim empirically: for a family of
//! noise distributions p_λ(y|x) ∝ p_D(y|x)^λ interpolating from uniform
//! (λ=0) to adversarial (λ=1), both the closed-form η̄ (Eq. 15) and a
//! Monte-Carlo estimate from actual stochastic gradients increase
//! monotonically in λ and peak at p_n = p_D.
//!
//! Run with: cargo run --release --example snr_demo

use adv_softmax::exp::snr::{run, SnrOpts};
use anyhow::Result;

fn main() -> Result<()> {
    let opts = SnrOpts::default();
    let points = run(&opts)?;

    let best = points
        .iter()
        .max_by(|a, b| a.analytic.total_cmp(&b.analytic))
        .unwrap();
    println!("\nmaximum eta-bar at: {}", best.name);
    assert!(
        best.name.contains("adversarial"),
        "Theorem 2 violated?! best was {}",
        best.name
    );

    // relative gain over uniform — the quantitative version of "drastically
    // enhanced gradient signal" from the abstract
    let uniform = &points[0];
    println!(
        "SNR gain over uniform negative sampling: {:.1}x (analytic), {:.1}x (monte-carlo)",
        best.analytic / uniform.analytic,
        best.monte_carlo / uniform.monte_carlo
    );
    Ok(())
}
