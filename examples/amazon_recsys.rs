//! Recommendation-style serving scenario (the paper's intro motivates
//! extreme classification with recommender systems and ranking).
//!
//! Trains the proposed method on the Amazon-670K stand-in, then serves a
//! stream of "user" queries: each query scores all C labels (chunked
//! through the MXU eval kernel, bias-corrected per Eq. 5) and returns the
//! top-1 "product". Reports serving latency percentiles and accuracy —
//! the numbers a deployment would care about.
//!
//! Run with: AMAZON_SECONDS=60 cargo run --release --example amazon_recsys

use adv_softmax::eval::Evaluator;
use adv_softmax::prelude::*;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let seconds: f64 = std::env::var("AMAZON_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(45.0);

    let syn = SyntheticConfig::preset(DatasetPreset::AmazonSim);
    let splits = Splits::synthetic(&syn);
    println!(
        "amazon-sim: N={} C={} K={}",
        splits.train.len(),
        splits.train.num_classes,
        splits.train.feat_dim
    );
    let registry = Registry::open_default()?;

    // --- train ---
    let mut cfg = RunConfig::new(DatasetPreset::AmazonSim, Method::Adversarial);
    cfg.max_seconds = seconds;
    cfg.max_steps = 100_000;
    cfg.eval_points = 1024;
    println!("training adversarial method for {seconds}s ...");
    let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
    let curve = run.train()?;
    let last = curve.last().expect("at least one checkpoint");
    println!(
        "trained {} steps in {:.1}s (incl. {:.1}s aux fit): acc {:.3}, loglik {:.3}",
        last.step, last.wall_s, curve.aux_fit_seconds, last.accuracy, last.log_likelihood
    );

    // --- serve: batched top-1 queries over the full catalog ---
    let evaluator = Evaluator::new(&registry)?;
    let batch = evaluator.eval_b;
    let mut rng = Rng::new(99);
    let n_batches = 16;
    let mut latencies = Vec::with_capacity(n_batches);
    let mut hits = 0usize;
    let mut total = 0usize;
    for _ in 0..n_batches {
        let queries = splits.test.subsample(batch, &mut rng);
        let t0 = Instant::now();
        let r = evaluator.evaluate(&run.params, &queries, run.aux.as_deref())?;
        latencies.push(t0.elapsed().as_secs_f64());
        hits += (r.accuracy * r.n as f64).round() as usize;
        total += r.n;
    }
    latencies.sort_by(|a, b| a.total_cmp(b));
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!("\n=== serving report ===");
    println!("catalog size          : {} labels", splits.train.num_classes);
    println!("query batch           : {batch}");
    println!(
        "batch latency p50/p90 : {:.1}ms / {:.1}ms",
        1e3 * p(0.5),
        1e3 * p(0.9)
    );
    println!(
        "throughput            : {:.0} queries/s",
        batch as f64 / p(0.5)
    );
    println!("top-1 hit rate        : {:.3}", hits as f64 / total as f64);
    Ok(())
}
