//! Appendix A.2 driver: full softmax vs plain negative sampling on a small
//! dataset (EURLex-4K stand-in) where optimizing Eq. 1 directly is
//! tractable.
//!
//! Paper's finding: softmax 33.6% vs uniform-NS 26.4% test accuracy — a
//! clear gap that motivates *why* a better negative-sampling scheme (the
//! paper's contribution) matters: plain NS pays a real accuracy price for
//! its O(K) updates.
//!
//! Run with: A2_SECONDS=60 cargo run --release --example eurlex_softmax_vs_ns

use adv_softmax::exp::appendix_a2::{run, A2Opts};
use adv_softmax::runtime::Registry;
use anyhow::Result;

fn main() -> Result<()> {
    let seconds: f64 = std::env::var("A2_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(45.0);
    let registry = Registry::open_default()?;
    let r = run(&registry, &A2Opts { seconds_per_method: seconds, ..Default::default() })?;
    println!(
        "\nshape check — softmax beats uniform NS: {} ({:.1}% vs {:.1}%)",
        if r.softmax_acc > r.uniform_acc { "YES" } else { "NO" },
        100.0 * r.softmax_acc,
        100.0 * r.uniform_acc,
    );
    println!("paper (EURLex-4K): 33.6% vs 26.4%");
    Ok(())
}
