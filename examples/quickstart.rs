//! Quickstart: the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Trains the paper's proposed method — adversarial negative sampling with
//! Eq. 5 bias removal — on a synthetic extreme-classification workload and
//! logs the full learning curve, then contrasts the final model against
//! plain uniform negative sampling under the same wallclock budget.
//!
//! Run with:
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//! Budget can be tuned via QUICKSTART_SECONDS (default 20s per method).

use adv_softmax::prelude::*;
use anyhow::Result;

fn main() -> Result<()> {
    let seconds: f64 = std::env::var("QUICKSTART_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);

    // 1. data: hierarchically-clustered synthetic XC workload (tiny preset:
    //    4096 train points, 256 classes — swap in WikiSim for the real run)
    let syn = SyntheticConfig::preset(DatasetPreset::Tiny);
    let splits = Splits::synthetic(&syn);
    println!(
        "dataset: N={} C={} K={}",
        splits.train.len(),
        splits.train.num_classes,
        splits.train.feat_dim
    );

    // 2. runtime: compile the AOT HLO artifacts once
    let registry = Registry::open_default()?;
    println!("artifacts: {:?}", registry.names());

    // 3. train the proposed method and the uniform baseline
    let mut curves = Vec::new();
    for method in [Method::Adversarial, Method::Uniform] {
        let mut cfg = RunConfig::new(DatasetPreset::Tiny, method);
        cfg.max_seconds = seconds;
        cfg.max_steps = 50_000;
        println!("\n--- training {method} (budget {seconds}s) ---");
        let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
        let curve = run.train()?;
        println!("step      wall_s   train_loss   test_loglik   test_acc");
        for p in &curve.points {
            println!(
                "{:>8} {:>8.1} {:>12.4} {:>13.4} {:>10.4}",
                p.step, p.wall_s, p.train_loss, p.log_likelihood, p.accuracy
            );
        }
        curves.push((method, curve));
    }

    // 4. compare
    println!("\n=== summary ===");
    for (method, curve) in &curves {
        println!(
            "{:<12} best acc {:.4}  best loglik {:.4}  (aux fit {:.1}s)",
            method.to_string(),
            curve.best_accuracy(),
            curve.best_log_likelihood(),
            curve.aux_fit_seconds
        );
    }
    // time-to-accuracy is the paper's headline statistic; on the tiny
    // preset both methods eventually saturate, so compare speed, not the
    // ceiling. The full-scale effect is `repro exp figure1 --dataset
    // wiki-sim` (EXPERIMENTS.md E2: >20x faster to target accuracy).
    let target = 0.9 * curves.iter().map(|(_, c)| c.best_accuracy()).fold(0.0, f64::max);
    for (method, curve) in &curves {
        match curve.time_to_accuracy(target) {
            Some(t) => println!("{method:<12} reached acc {target:.3} at {t:.1}s"),
            None => println!("{method:<12} never reached acc {target:.3}"),
        }
    }
    Ok(())
}
